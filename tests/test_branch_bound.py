"""Branch-and-bound driver: proven optimality vs brute force on the MIP
fixtures, warm-start pivot wins, stream/dispatch agreement, and the
bound-edit plumbing underneath (with_bounds / rebind_bounds /
safe_dual_bound / the supports_safe_bound registry contract)."""
import itertools

import numpy as np
import pytest

from repro.core import (BACKEND_REGISTRY, INFEASIBLE, ITERATION_LIMIT,
                        OPTIMAL, GeneralLPBatch, branch_and_bound,
                        backend_spec, canonicalize, general_violation,
                        rebind_bounds, safe_dual_bound,
                        random_general_lp_batch, solve_batched_reference)
from repro.io.mps import MIP_FIXTURE_NAMES, fixture_path, read_mps

# brute-force optima, re-derivable with brute_force_mip() below
FIXTURE_OPT = {"knapsack": 280.0, "assignment": 5.0, "scheduling": 42.0}


def brute_force_mip(g: GeneralLPBatch):
    """Enumerate every integer point in the bound box (fixtures are sized
    to keep this in the low thousands) — the oracle the driver is held to."""
    lb = g.lb[0].astype(int)
    ub = g.ub[0].astype(int)
    best, bx = np.inf, None
    for xs in itertools.product(*[range(l, u + 1) for l, u in zip(lb, ub)]):
        x = np.asarray(xs, np.float64)
        if general_violation(g, x[None])[0] > 1e-9:
            continue
        v = float(g.objective_value(x[None])[0])
        v = -v if g.maximize else v
        if v < best:
            best, bx = v, x
    return (-best if g.maximize else best), bx


def _tiny_knapsack():
    v = np.array([[10.0, 6.0, 4.0]])
    w = np.array([[[5.0, 4.0, 3.0]]])
    return GeneralLPBatch.from_arrays(
        A=w, sense=["L"], rhs=[[9.0]], lb=np.zeros((1, 3)),
        ub=np.ones((1, 3)), c=v, maximize=True, integer=np.ones(3, bool))


# ---------------------------------------------------------------------------
# fixtures to proven optimality, cross-checked against brute force
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", MIP_FIXTURE_NAMES)
@pytest.mark.parametrize("backend", ["tableau", "revised"])
def test_fixtures_proven_optimal_exact_backends(name, backend):
    g = read_mps(fixture_path(name))
    opt, _ = brute_force_mip(g)
    assert abs(opt - FIXTURE_OPT[name]) < 1e-9   # recorded optimum is right
    res = branch_and_bound(g, backend=backend, frontier=8)
    assert res.status == OPTIMAL and res.proven
    assert abs(res.objective - opt) < 1e-5
    assert abs(res.bound - opt) < 1e-5 and res.gap == 0.0
    # incumbent is exactly integral and feasible in original coordinates
    xi = res.x[np.flatnonzero(g.integer)]
    assert np.array_equal(xi, np.round(xi))
    assert general_violation(g, res.x[None])[0] < 1e-7


@pytest.mark.parametrize("name", ["knapsack", "scheduling"])
def test_fixtures_pdhg_safe_bound_pass(name):
    """PDHG relaxations are tolerance-based: fathoming must survive on the
    safe_dual_bound certificate alone and still prove the optimum."""
    g = read_mps(fixture_path(name))
    res = branch_and_bound(g, backend="pdhg", frontier=8, max_nodes=200)
    assert res.status == OPTIMAL and res.proven
    assert abs(res.objective - FIXTURE_OPT[name]) < 1e-3


def test_stream_matches_dispatch():
    g = read_mps(fixture_path("scheduling"))
    a = branch_and_bound(g, backend="tableau", mode="dispatch", frontier=8)
    b = branch_and_bound(g, backend="tableau", mode="stream", frontier=8,
                         lanes=8)
    assert a.status == b.status == OPTIMAL
    assert abs(a.objective - b.objective) < 1e-6
    np.testing.assert_allclose(a.x, b.x)


def test_warm_start_reduces_pivots():
    """The tentpole's payoff: children re-solved from the parent basis take
    measurably fewer simplex iterations than cold solves of the same tree."""
    g = read_mps(fixture_path("knapsack"))
    warm = branch_and_bound(g, backend="tableau", frontier=8)
    cold = branch_and_bound(g, backend="tableau", frontier=8,
                            warm_start=False)
    assert warm.objective == cold.objective == FIXTURE_OPT["knapsack"]
    assert warm.nodes == cold.nodes          # same tree, same fathoming
    assert warm.lp_iterations < cold.lp_iterations


def test_search_orders_agree():
    g = read_mps(fixture_path("scheduling"))
    best = branch_and_bound(g, search="best", frontier=4)
    dive = branch_and_bound(g, search="depth", frontier=4)
    assert best.proven and dive.proven
    assert abs(best.objective - dive.objective) < 1e-6


# ---------------------------------------------------------------------------
# verdict edge cases
# ---------------------------------------------------------------------------

def test_integer_infeasible_is_proven():
    """LP-feasible but integer-infeasible: x1 + x2 == 0.5 over binaries."""
    g = GeneralLPBatch.from_arrays(
        A=[[[1.0, 1.0]]], sense=["E"], rhs=[[0.5]], lb=np.zeros((1, 2)),
        ub=np.ones((1, 2)), c=[[1.0, 1.0]], integer=np.ones(2, bool))
    res = branch_and_bound(g, frontier=4)
    assert res.status == INFEASIBLE and res.proven and res.x is None


def test_node_budget_brackets_optimum():
    g = read_mps(fixture_path("scheduling"))
    res = branch_and_bound(g, frontier=1, max_nodes=3)
    assert res.status == ITERATION_LIMIT and not res.proven
    assert res.nodes <= 3
    # min sense: the surviving bound must stay below the true optimum
    assert res.bound <= FIXTURE_OPT["scheduling"] + 1e-6


def test_input_validation():
    g = _tiny_knapsack()
    with pytest.raises(ValueError, match="mode"):
        branch_and_bound(g, mode="nope")
    with pytest.raises(ValueError, match="search"):
        branch_and_bound(g, search="nope")
    with pytest.raises(ValueError, match="stream"):
        branch_and_bound(g, mode="stream", backend="revised")
    with pytest.raises(ValueError, match="no integer"):
        branch_and_bound(GeneralLPBatch.from_arrays(
            A=[[[1.0]]], sense=["L"], rhs=[[1.0]], c=[[1.0]]))
    free = GeneralLPBatch.from_arrays(
        A=[[[1.0]]], sense=["L"], rhs=[[1.0]], c=[[1.0]],
        integer=[0])                      # default ub is +inf
    with pytest.raises(ValueError, match="finite"):
        branch_and_bound(free)


def test_registry_safe_bound_contract():
    """Every shipped backend advertises safe bounds; the driver gates
    non-exact engines on the flag."""
    for name in BACKEND_REGISTRY:
        assert backend_spec(name).supports_safe_bound, name
    # exact engines may participate regardless of the flag
    assert backend_spec("tableau").exact
    assert not backend_spec("pdhg").exact


# ---------------------------------------------------------------------------
# the bound-edit plumbing
# ---------------------------------------------------------------------------

def test_with_bounds_shapes_and_broadcast():
    g = _tiny_knapsack()
    g2 = g.with_bounds(ub=np.zeros(3))            # (n,) broadcast
    assert g2.ub.shape == (1, 3) and (g2.ub == 0).all()
    assert (g.ub == 1).all()                      # original untouched
    stack = np.stack([np.zeros(3), np.ones(3)])   # (B', n) batch expansion
    g4 = g.with_bounds(ub=stack)
    assert g4.batch == 2 and g4.A.shape == (2, 1, 3)
    with pytest.raises(ValueError, match="lb > ub"):
        g.with_bounds(lb=np.full(3, 2.0))
    with pytest.raises(ValueError):
        g.with_bounds(ub=np.ones(4))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rebind_bounds_matches_full_canonicalize(seed):
    """The cheap bound-edit path must produce the same canonical batch and
    recovery numbers as canonicalizing the edited general form from
    scratch (given the root's frozen structure)."""
    rng = np.random.default_rng(seed)
    g = random_general_lp_batch(rng, 1, 6, 5)
    # finite boxes so nudged bounds keep the finiteness pattern
    g = g.with_bounds(lb=np.zeros((1, 5)), ub=np.full((1, 5), 4.0))
    lp0, rec0 = canonicalize(g)
    lbs = np.repeat(g.lb, 3, axis=0) + rng.uniform(0, 1, (3, 5))
    ubs = np.repeat(g.ub, 3, axis=0) - rng.uniform(0, 1, (3, 5))
    lp_f, rec_f = rebind_bounds(lp0, rec0, lbs, ubs)
    g_f = g.with_bounds(lb=lbs, ub=ubs)
    lp_ref, rec_ref = canonicalize(g_f)
    np.testing.assert_allclose(np.broadcast_to(
        np.asarray(lp_f.A), np.asarray(lp_ref.A).shape), lp_ref.A)
    np.testing.assert_allclose(lp_f.b, lp_ref.b)
    np.testing.assert_allclose(np.broadcast_to(
        np.asarray(lp_f.c), np.asarray(lp_ref.c).shape), lp_ref.c)
    np.testing.assert_allclose(lp_f.upper_bounds(), lp_ref.upper_bounds())
    np.testing.assert_allclose(rec_f.baseline, rec_ref.baseline)
    np.testing.assert_allclose(rec_f.shift, rec_ref.shift)
    res_f = solve_batched_reference(lp_f)
    res_ref = solve_batched_reference(lp_ref)
    np.testing.assert_allclose(rec_f.recover(res_f).objective,
                               rec_ref.recover(res_ref).objective,
                               rtol=1e-9, atol=1e-9)


def test_safe_dual_bound_validity_and_tightness():
    """For any y the bound must under(over)-estimate the min(max); with the
    true optimal duals it must be tight."""
    rng = np.random.default_rng(3)
    for name in ("knapsack", "scheduling"):
        g = read_mps(fixture_path(name))
        ref = solve_batched_reference(g)
        assert ref.status[0] == OPTIMAL
        opt = float(ref.objective[0])
        y_opt = np.asarray(ref.y)        # PR 5 certificate, original rows
        tight = float(safe_dual_bound(g, y_opt)[0])
        slack_dir = -1.0 if g.maximize else 1.0
        # validity for random, zero, and NaN-poisoned duals
        for y in (np.zeros((1, g.m)), rng.normal(size=(1, g.m)),
                  np.full((1, g.m), np.nan), y_opt):
            sb = float(safe_dual_bound(g, y)[0])
            assert slack_dir * (opt - sb) >= -1e-7 * (1 + abs(opt))
        assert abs(tight - opt) < 1e-6 * (1 + abs(opt))
