"""General-form pipeline: canonicalize -> solve -> recover round-trips.

Property tests over random general-form batches (mixed senses, bounds,
frees, ranges, min/max) plus the vendored MPS fixtures: the canonical form
must match the float64 oracle, recovered objectives must equal c.x in
original coordinates bit-consistently across backends and pricing rules,
and presolve scaling must never change exact-arithmetic statuses.
"""
import numpy as np
import pytest

from repro.core import (GeneralLPBatch, INFEASIBLE, LPBatch, OPTIMAL,
                        UNBOUNDED, canonical_shape, canonicalize,
                        general_violation, random_general_lp_batch,
                        solve_batched, solve_batched_jax,
                        solve_batched_reference)
from repro.core.forms import EQ, GE, LE, ensure_canonical

RNG = np.random.default_rng(11)


def _general(B=8, m=7, n=6, **kw):
    return random_general_lp_batch(RNG, B, m, n, **kw)


# ---------------------------------------------------------------------------
# canonicalize mechanics
# ---------------------------------------------------------------------------

def test_canonical_shape_growth():
    # equalities double, frees add columns; finite ubs are native (no rows)
    g = GeneralLPBatch.from_arrays(
        A=np.ones((1, 3, 2)), sense=[LE, GE, EQ], rhs=[[3.0, 1.0, 2.0]],
        lb=[[0.0, -np.inf]], ub=[[5.0, np.inf]], c=[[1.0, 1.0]])
    m_can, n_can = canonical_shape(g)
    # rows: 1 (L hi) + 1 (E hi) + 1 (G lo) + 1 (E lo) = 4; the finite ub
    # rides the bound vector instead of an identity row
    assert (m_can, n_can) == (4, 3)   # one free column split
    # legacy counterfactual: the row encoding would have paid one more row
    assert canonical_shape(g, bound_rows=True) == (5, 3)


def test_lower_bound_shift_and_constant():
    # min 2x + 3  s.t. x >= 4, x <= 9  -> optimum 11 at x = 4
    g = GeneralLPBatch.from_arrays(
        A=np.zeros((1, 1, 1)), sense=[LE], rhs=[[0.0]],
        lb=[[4.0]], ub=[[9.0]], c=[[2.0]], c0=3.0)
    res = solve_batched_reference(g)
    assert res.status[0] == OPTIMAL
    np.testing.assert_allclose(res.objective[0], 11.0)
    np.testing.assert_allclose(res.x[0], [4.0])


def test_maximize_sense():
    g = GeneralLPBatch.from_arrays(
        A=[[[1.0, 1.0]]], sense=[LE], rhs=[[4.0]], c=[[1.0, 2.0]],
        maximize=True)
    res = solve_batched_reference(g)
    np.testing.assert_allclose(res.objective[0], 8.0)


def test_free_variable_split():
    # min x  s.t.  x >= -5 encoded via a G row on a free variable
    g = GeneralLPBatch.from_arrays(
        A=[[[1.0]]], sense=[GE], rhs=[[-5.0]],
        lb=[[-np.inf]], c=[[1.0]])
    res = solve_batched_reference(g)
    assert res.status[0] == OPTIMAL
    np.testing.assert_allclose(res.objective[0], -5.0)
    np.testing.assert_allclose(res.x[0], [-5.0])


def test_ranged_rows():
    # 2 <= x1 + x2 <= 5 via an L row with a range; max x1 + x2
    g = GeneralLPBatch.from_arrays(
        A=[[[1.0, 1.0]]], sense=[LE], rhs=[[5.0]], ranges=[3.0],
        ub=[[4.0, 4.0]], c=[[1.0, 1.0]], maximize=True)
    res = solve_batched_reference(g)
    np.testing.assert_allclose(res.objective[0], 5.0)
    # minimize instead: floor of the range binds
    g2 = GeneralLPBatch.from_arrays(
        A=[[[1.0, 1.0]]], sense=[LE], rhs=[[5.0]], ranges=[3.0],
        ub=[[4.0, 4.0]], c=[[1.0, 1.0]])
    np.testing.assert_allclose(solve_batched_reference(g2).objective[0], 2.0)


def test_presolve_fixed_and_empty():
    # x0 fixed at 2 (substituted into the row), x2 empty column at its
    # cost-optimal bound; both removed from the canonical form
    g = GeneralLPBatch.from_arrays(
        A=[[[1.0, 1.0, 0.0]]], sense=[LE], rhs=[[10.0]],
        lb=[[2.0, 0.0, 0.0]], ub=[[2.0, np.inf, 7.0]],
        c=[[1.0, 1.0, 1.0]], maximize=True)
    lp, rec = canonicalize(g)
    assert lp.n == 1 and lp.m == 1
    res = solve_batched_reference(g)
    np.testing.assert_allclose(res.objective[0], 2.0 + 8.0 + 7.0)
    np.testing.assert_allclose(res.x[0], [2.0, 8.0, 7.0])


def test_presolve_empty_row_infeasible():
    A = np.zeros((2, 1, 1))
    g = GeneralLPBatch.from_arrays(
        A=A, sense=[GE], rhs=np.array([[1.0], [-1.0]]), c=np.zeros((2, 1)))
    res = solve_batched_reference(g)
    assert res.status[0] == INFEASIBLE       # 0 >= 1 impossible
    assert res.status[1] == OPTIMAL          # 0 >= -1 fine


def test_unbounded_general():
    g = GeneralLPBatch.from_arrays(   # min -x with x unconstrained above
        A=[[[0.0]]], sense=[LE], rhs=[[1.0]], c=[[-1.0]])
    assert solve_batched_reference(g).status[0] == UNBOUNDED


def test_empty_free_column_unbounded_not_presolved():
    # min y, y free-below with a finite ub and no constraint rows touching
    # it: the optimizing bound is -inf, so presolve must NOT substitute the
    # finite ub (that would certify a fake OPTIMAL at y = ub)
    g = GeneralLPBatch.from_arrays(
        A=[[[1.0, 0.0]]], sense=[LE], rhs=[[4.0]],
        lb=[[0.0, -np.inf]], ub=[[np.inf, 5.0]], c=[[0.0, 1.0]])
    for presolve in (True, False):
        assert solve_batched_reference(g, presolve=presolve).status[0] \
            == UNBOUNDED, presolve
    # flipped cost: ub IS the optimizing bound — presolve may drop it
    g2 = GeneralLPBatch.from_arrays(
        A=[[[1.0, 0.0]]], sense=[LE], rhs=[[4.0]],
        lb=[[0.0, -np.inf]], ub=[[np.inf, 5.0]], c=[[0.0, -1.0]])
    res = solve_batched_reference(g2)
    assert res.status[0] == OPTIMAL
    np.testing.assert_allclose(res.x[0, 1], 5.0)


def test_scaling_is_pow2_and_invertible():
    g = _general(B=4)
    lp_s, rec_s = canonicalize(g, scale=True)
    lp_u, rec_u = canonicalize(g, scale=False)
    r, s = rec_s.row_scale, rec_s.col_scale
    for arr in (r, s):
        fr, _ = np.frexp(arr)
        assert np.all(fr == 0.5), "scales must be powers of two"
    back = lp_s.A / r[:, :, None] / s[:, None, :]
    np.testing.assert_array_equal(back, lp_u.A)


def test_ensure_canonical_passthrough():
    lp = LPBatch.from_arrays(np.ones((2, 3, 4)), np.ones((2, 3)),
                             np.ones((2, 4)))
    out, rec = ensure_canonical(lp)
    assert out is lp and rec is None


def test_mixed_bound_finiteness_rejected():
    lb = np.array([[0.0], [-np.inf]])
    g = GeneralLPBatch.from_arrays(
        A=np.ones((2, 1, 1)), sense=[LE], rhs=np.ones((2, 1)), lb=lb,
        c=np.ones((2, 1)))
    with pytest.raises(ValueError, match="batch-uniform"):
        canonicalize(g)


# ---------------------------------------------------------------------------
# canonicalize -> solve -> recover round-trip properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,kw", [
    (0, {}),
    (1, {"eq_frac": 0.5}),
    (2, {"free_frac": 0.3}),
    (3, {"ranged_frac": 0.4}),
    (4, {"eq_frac": 0.3, "free_frac": 0.2, "ranged_frac": 0.3}),
])
def test_roundtrip_matches_scipy(seed, kw):
    """The whole pipeline (canonicalize -> f64 oracle -> recover) must agree
    with an independent general-form solver on statuses and objectives."""
    scipy_opt = pytest.importorskip("scipy.optimize")
    rng = np.random.default_rng(seed)
    g = random_general_lp_batch(rng, B=6, m=6, n=5, **kw)
    res = solve_batched_reference(g)
    lo, hi = g.row_bounds()
    for k in range(g.batch):
        fin_hi = np.isfinite(hi[k])
        fin_lo = np.isfinite(lo[k])
        A_ub = np.vstack([g.A[k][fin_hi], -g.A[k][fin_lo]])
        b_ub = np.concatenate([hi[k][fin_hi], -lo[k][fin_lo]])
        sign = -1.0 if g.maximize else 1.0
        sp = scipy_opt.linprog(sign * g.c[k], A_ub=A_ub, b_ub=b_ub,
                               bounds=list(zip(g.lb[k], g.ub[k])),
                               method="highs")
        want = {0: OPTIMAL, 2: INFEASIBLE, 3: UNBOUNDED}.get(sp.status)
        assert res.status[k] == want, f"LP {k}: {res.status[k]} vs scipy {want}"
        if want == OPTIMAL:
            obj_sp = sign * sp.fun + g.c0[k]
            np.testing.assert_allclose(res.objective[k], obj_sp, rtol=1e-7,
                                       atol=1e-7)
            assert general_violation(g, res.x)[k] < 1e-7


@pytest.mark.parametrize("backend,pricing", [
    ("tableau", "dantzig"), ("tableau", "steepest_edge"),
    ("tableau", "devex"), ("revised", "dantzig"), ("revised", "partial"),
])
def test_recovered_objective_is_c_dot_x(backend, pricing):
    """Recovered objectives must equal c.x + c0 in original coordinates
    bit-consistently (the recovery recomputes them from the recovered x)."""
    g = _general(B=12, m=6, n=6, eq_frac=0.3)
    res = solve_batched_jax(g, backend=backend, pricing=pricing)
    ok = res.status == OPTIMAL
    assert ok.any()
    recomputed = np.einsum("bn,bn->b", g.c, res.x) + g.c0
    np.testing.assert_array_equal(res.objective[ok], recomputed[ok])
    assert np.isnan(res.objective[~ok]).all()


def test_backends_agree_on_general_batches():
    g = _general(B=16, m=7, n=7, eq_frac=0.3, ranged_frac=0.2)
    ref = solve_batched_reference(g)
    tab = solve_batched_jax(g)
    rev = solve_batched_jax(g, backend="revised")
    assert (ref.status == tab.status).mean() >= 0.9
    assert (ref.status == rev.status).mean() >= 0.9
    ok = (ref.status == OPTIMAL) & (tab.status == OPTIMAL) \
        & (rev.status == OPTIMAL)
    assert ok.any()
    scale = np.maximum(1.0, np.abs(ref.objective[ok]))
    assert (np.abs(tab.objective[ok] - ref.objective[ok]) / scale).max() < 2e-3
    assert (np.abs(rev.objective[ok] - ref.objective[ok]) / scale).max() < 2e-3


def test_scaling_never_changes_oracle_statuses():
    for seed in range(3):
        rng = np.random.default_rng(100 + seed)
        g = random_general_lp_batch(rng, B=10, m=6, n=6, eq_frac=0.3,
                                    free_frac=0.2)
        s1 = solve_batched_reference(g, scale=True).status
        s0 = solve_batched_reference(g, scale=False).status
        np.testing.assert_array_equal(s1, s0)


def test_presolve_off_still_correct():
    g = _general(B=6, m=6, n=5)
    a = solve_batched_reference(g, presolve=True)
    b = solve_batched_reference(g, presolve=False)
    np.testing.assert_array_equal(a.status, b.status)
    ok = a.status == OPTIMAL
    np.testing.assert_allclose(a.objective[ok], b.objective[ok], rtol=1e-9)


def test_solve_batched_chunked_general():
    """solve_batched canonicalizes once and recovers the concatenated
    result across chunks."""
    g = _general(B=24, m=5, n=5)
    whole = solve_batched(g)
    chunked = solve_batched(g, chunk_size=7)
    np.testing.assert_array_equal(whole.status, chunked.status)
    ok = whole.status == OPTIMAL
    np.testing.assert_allclose(whole.objective[ok], chunked.objective[ok],
                               rtol=1e-6)
    assert whole.x.shape == (24, g.n)


def test_general_through_distributed_and_pallas():
    """The remaining entry points accept GeneralLPBatch directly: pjit,
    shard_map (one-shot and segmented) and the Pallas kernel all report in
    original coordinates."""
    import jax
    from jax.sharding import Mesh
    from repro.core import solve_pjit, solve_shard_map
    from repro.kernels.ops import solve_batched_pallas

    g = _general(B=8, m=5, n=5, eq_frac=0.3)
    ref = solve_batched_reference(g)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    outs = {
        "pjit": solve_pjit(g, mesh),
        "shard_map": solve_shard_map(g, mesh),
        "shard_map_seg": solve_shard_map(g, mesh, segment_k=8),
        "pallas": solve_batched_pallas(g),
        "pallas_compact": solve_batched_pallas(g, compaction=True,
                                               segment_k=8),
    }
    for name, res in outs.items():
        assert res.x.shape == (8, g.n), name
        assert (res.status == ref.status).mean() >= 0.85, name
        ok = (res.status == OPTIMAL) & (ref.status == OPTIMAL)
        scale = np.maximum(1.0, np.abs(ref.objective[ok]))
        err = np.abs(res.objective[ok] - ref.objective[ok]) / scale
        assert err.max() < 2e-3, name


def test_pallas_revised_runs_kernel_without_fallback():
    """General-form batches through solve_batched_pallas(backend="revised")
    run the tile kernel — no fallback warning may fire, and the recovered
    result must match the pure-JAX revised path."""
    import warnings as _w
    from repro.core.revised import solve_batched_revised
    from repro.kernels import ops
    from repro.kernels.ops import solve_batched_pallas

    g = _general(B=4, m=4, n=4)
    ref = solve_batched_revised(g)
    ops._WARNED.discard("revised-fallback")
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        res = solve_batched_pallas(g, backend="revised", tile_b=4)
    hits = [x for x in rec if "falling back" in str(x.message)]
    assert not hits, "revised has a Pallas kernel; no fallback may fire"
    np.testing.assert_array_equal(res.status, ref.status)
    ok = res.status == OPTIMAL
    scale = np.maximum(1.0, np.abs(ref.objective[ok]))
    assert (np.abs(res.objective[ok] - ref.objective[ok]) / scale).max() \
        < 1e-4


def test_artificial_pinning_on_degenerate_equalities():
    """The phase-2 artificial-pinning rule: equality-pair canonical forms
    must not silently relax their rows (this failed before the fix)."""
    rng = np.random.default_rng(5)
    for _ in range(3):
        g = random_general_lp_batch(rng, B=8, m=8, n=6, eq_frac=0.6)
        res = solve_batched_reference(g)
        ok = res.status == OPTIMAL
        assert general_violation(g, res.x)[ok].max() < 1e-6
