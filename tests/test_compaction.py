"""Active-set compaction scheduler correctness (core/compaction.py).

Gathering survivors into smaller buckets never touches any LP's own tableau,
so the scheduled solve must be *bit-identical* to the monolithic
phase-compacted solver — and both must match the float64 NumPy oracle on
status for these well-conditioned batches."""
import numpy as np
import pytest

from repro.core import (INFEASIBLE, OPTIMAL, UNBOUNDED, LPBatch,
                        random_lp_batch, solve_batched, solve_batched_compacted,
                        solve_batched_jax, solve_batched_reference)
from repro.core.compaction import next_bucket, total_elements
from repro.core.simplex import tableau_elements

RNG = np.random.default_rng(5)


def _mixed_statuses_batch(rng, B_each=10, m=8, n=6):
    """OPTIMAL + INFEASIBLE + UNBOUNDED LPs in one randomly permuted batch."""
    feas = random_lp_batch(rng, B_each, m, n, feasible_start=True)
    p1 = random_lp_batch(rng, B_each, m, n, feasible_start=False)
    # infeasible: first row forces x_0 <= -1 with x >= 0
    inf = random_lp_batch(rng, B_each, m, n, feasible_start=True)
    A_inf, b_inf = inf.A.copy(), inf.b.copy()
    A_inf[:, 0, :] = 0.0
    A_inf[:, 0, 0] = 1.0
    b_inf[:, 0] = -1.0
    # unbounded: only constrain x_1.., leave x_0 free to grow
    unb = random_lp_batch(rng, B_each, m, n, feasible_start=True)
    A_unb = unb.A.copy()
    A_unb[:, :, 0] = 0.0
    c_unb = unb.c.copy()
    c_unb[:, 0] = 1.0
    batch = LPBatch(
        A=np.concatenate([feas.A, p1.A, A_inf, A_unb]),
        b=np.concatenate([feas.b, p1.b, b_inf, unb.b]),
        c=np.concatenate([feas.c, p1.c, inf.c, c_unb]))
    perm = rng.permutation(batch.batch)
    return LPBatch(A=batch.A[perm], b=batch.b[perm], c=batch.c[perm])


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(a.status, b.status)
    np.testing.assert_array_equal(a.iterations, b.iterations)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(np.nan_to_num(a.objective),
                                  np.nan_to_num(b.objective))


@pytest.mark.parametrize("segment_k", [1, 4, 16])
def test_scheduled_bitwise_matches_monolithic(segment_k):
    batch = _mixed_statuses_batch(np.random.default_rng(17))
    mono = solve_batched_jax(batch)
    sched = solve_batched_compacted(batch, segment_k=segment_k)
    _assert_bitwise(mono, sched)
    # the batch really exercises every terminal status
    for code in (OPTIMAL, INFEASIBLE, UNBOUNDED):
        assert (sched.status == code).any()


def test_matches_oracle_status_and_objective():
    batch = _mixed_statuses_batch(np.random.default_rng(23))
    ref = solve_batched_reference(batch)
    sched = solve_batched_compacted(batch, segment_k=4)
    np.testing.assert_array_equal(ref.status, sched.status)
    ok = ref.status == OPTIMAL
    rel = np.abs(ref.objective[ok] - sched.objective[ok]) \
        / np.abs(ref.objective[ok])
    assert rel.max() < 2e-3
    # x agrees where optimal (f32 vs f64 pivots, same sequence)
    assert np.abs(ref.x[ok] - sched.x[ok]).max() \
        / max(1.0, np.abs(ref.x[ok]).max()) < 2e-3


def test_permutation_invariance():
    rng = np.random.default_rng(31)
    batch = _mixed_statuses_batch(rng)
    base = solve_batched_compacted(batch, segment_k=4)
    perm = rng.permutation(batch.batch)
    permuted = LPBatch(A=batch.A[perm], b=batch.b[perm], c=batch.c[perm])
    res = solve_batched_compacted(permuted, segment_k=4)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    np.testing.assert_array_equal(base.status, res.status[inv])
    np.testing.assert_array_equal(base.iterations, res.iterations[inv])
    np.testing.assert_array_equal(np.nan_to_num(base.objective),
                                  np.nan_to_num(res.objective[inv]))


def test_single_lp_batch():
    batch = random_lp_batch(np.random.default_rng(3), 1, 6, 4,
                            feasible_start=False)
    mono = solve_batched_jax(batch)
    sched = solve_batched_compacted(batch, segment_k=2)
    _assert_bitwise(mono, sched)


def test_all_converged_early():
    """Every LP finishes inside the first segment -> one segment per stage,
    no compaction, still correct."""
    # max 0 s.t. x <= 1: terminates on the first phase-2 optimality check
    B, m, n = 7, 3, 3
    A = np.tile(np.eye(m, n)[None], (B, 1, 1))
    b = np.ones((B, m))
    c = np.zeros((B, n))
    batch = LPBatch(A=A, b=b, c=c)
    mono = solve_batched_jax(batch)
    sched = solve_batched_compacted(batch, segment_k=64)
    _assert_bitwise(mono, sched)
    assert (sched.status == OPTIMAL).all()
    assert (sched.iterations == 0).all()


def test_stats_accounting():
    batch = _mixed_statuses_batch(np.random.default_rng(41))
    stats = []
    solve_batched_compacted(batch, segment_k=4, stats_out=stats)
    m, n = batch.m, batch.n
    for s in stats:
        assert s.stage in ("p1", "p2")
        per = tableau_elements(m, n, compacted=(s.stage == "p2"))
        assert s.elements == s.steps * s.bucket * per
        assert 0 < s.steps <= 4
    # buckets only ever shrink, and p1 segments precede p2 segments
    stages = [s.stage for s in stats]
    assert stages == sorted(stages)  # "p1" < "p2"
    buckets = [s.bucket for s in stats]
    assert buckets == sorted(buckets, reverse=True)
    assert total_elements(stats) > 0


def test_compaction_reduces_work_on_skewed_batch():
    """A batch with a heavy tail: most LPs trivial, a few long — the bucket
    ladder must retire the trivial ones."""
    rng = np.random.default_rng(59)
    easy_m, n = 8, 6
    hard = random_lp_batch(rng, 8, easy_m, n, feasible_start=False)
    B_easy = 120
    A = np.tile(np.eye(easy_m, n)[None], (B_easy, 1, 1))
    batch = LPBatch(A=np.concatenate([A * 1.0, hard.A]),
                    b=np.concatenate([np.ones((B_easy, easy_m)), hard.b]),
                    c=np.concatenate([np.zeros((B_easy, n)), hard.c]))
    stats_on, stats_off = [], []
    on = solve_batched_compacted(batch, segment_k=4, compact_threshold=0.5,
                                 stats_out=stats_on)
    off = solve_batched_compacted(batch, segment_k=4, compact_threshold=0.0,
                                  stats_out=stats_off)
    _assert_bitwise(on, off)
    assert total_elements(stats_on) < 0.5 * total_elements(stats_off)
    assert min(s.bucket for s in stats_on) <= 16


def test_solve_batched_compaction_kwarg():
    batch = _mixed_statuses_batch(np.random.default_rng(67))
    plain = solve_batched(batch, chunk_size=16)
    comp = solve_batched(batch, chunk_size=16, compaction=True, segment_k=4)
    np.testing.assert_array_equal(plain.status, comp.status)
    np.testing.assert_array_equal(plain.iterations, comp.iterations)


def test_next_bucket_ladder():
    assert next_bucket(1) == 1
    assert next_bucket(3) == 4
    assert next_bucket(4) == 4
    assert next_bucket(5) == 8
    assert next_bucket(5, pad_multiple=8) == 8
    assert next_bucket(9, pad_multiple=8) == 16
    assert next_bucket(3, pad_multiple=8) == 8
