"""MPS front end: parse the vendored fixtures, round-trip through the
writer, expand into paper-style batches, and solve end-to-end through every
entry point with float64-oracle certificates in original coordinates."""
import os
import warnings

import numpy as np
import pytest

from repro.core import (OPTIMAL, general_violation, solve_batched,
                        solve_batched_jax, solve_batched_reference)
from repro.io.mps import (FIXTURE_NAMES, MIP_FIXTURE_NAMES, fixture_path,
                          perturbed_batch, read_mps, write_mps)

AFIRO_OPT = -464.7531428571429       # published Netlib optimum
TESTPROB_OPT = -13.0
SC50B_LIKE_OPT = -2908.473039215686  # scipy/HiGHS float64 reference
SC205_LIKE_OPT = 3859.009119857473   # float64 oracle (min; all-UP staircase)


def _equal(g, g2):
    assert np.array_equal(g.A, g2.A)
    assert np.array_equal(g.rhs, g2.rhs)
    assert np.array_equal(g.c, g2.c)
    assert np.array_equal(g.c0, g2.c0)
    assert np.array_equal(g.lb, g2.lb)
    assert np.array_equal(g.ub, g2.ub)
    assert np.array_equal(g.sense, g2.sense)
    assert g.maximize == g2.maximize
    if g.ranges is None:
        assert g2.ranges is None or not np.isfinite(g2.ranges).any()
    else:
        np.testing.assert_array_equal(np.nan_to_num(g.ranges, nan=-1.0),
                                      np.nan_to_num(g2.ranges, nan=-1.0))
    assert g.row_names == g2.row_names
    assert g.col_names == g2.col_names


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

def test_parse_afiro_structure():
    g = read_mps(fixture_path("afiro"))
    assert (g.m, g.n) == (27, 32)
    assert int((g.sense == "E").sum()) == 8
    assert int((g.sense == "L").sum()) == 19
    assert not g.maximize
    assert int((g.A != 0).sum()) + int((g.c != 0).sum()) == 88
    # canonical shape matches the paper's Table-5 converted AFIRO size
    from repro.core import canonical_shape
    assert canonical_shape(g) == (35, 32)


def test_parse_testprob_bounds():
    g = read_mps(fixture_path("testprob"))
    assert (g.m, g.n) == (3, 3)
    j = g.col_names.index("X2")
    assert np.isneginf(g.lb[0, j])           # MI bound
    i = g.col_names.index("X1")
    assert g.ub[0, i] == 4.0                 # UP bound


def test_parse_sc50b_like_features():
    g = read_mps(fixture_path("sc50b_like"))
    assert (g.m, g.n) == (50, 48)
    assert set(np.unique(g.sense)) == {"E", "G", "L"}
    assert g.ranges is not None and np.isfinite(g.ranges).sum() == 5
    fx = g.col_names.index("INV0")
    assert g.lb[0, fx] == g.ub[0, fx] == 10.0        # FX
    fr = g.col_names.index("EM0")
    assert np.isneginf(g.lb[0, fr]) and np.isinf(g.ub[0, fr])   # FR
    mi = g.col_names.index("OF7")
    assert np.isneginf(g.lb[0, mi]) and g.ub[0, mi] == 30.0     # MI + UP


def test_parse_errors():
    import tempfile
    with tempfile.NamedTemporaryFile("w", suffix=".mps", delete=False) as f:
        f.write("NAME X\nROWS\n L  R1\nCOLUMNS\n    C1  BOGUS  1.0\nENDATA\n")
        path = f.name
    with pytest.raises(ValueError, match="no objective"):
        read_mps(path)
    os.unlink(path)


# ---------------------------------------------------------------------------
# writer round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", FIXTURE_NAMES + MIP_FIXTURE_NAMES)
def test_roundtrip(tmp_path, name):
    g = read_mps(fixture_path(name))
    out = str(tmp_path / f"{name}_rt.mps")
    write_mps(g, out)
    g2 = read_mps(out)
    _equal(g, g2)
    if g.integer is None:
        assert g2.integer is None
    else:
        assert np.array_equal(g.integer, g2.integer)


def test_roundtrip_preserves_empty_columns(tmp_path):
    """A column with no nonzero A entries and zero cost must survive the
    write/read round-trip (the writer declares it via an explicit 0.0
    objective entry)."""
    from repro.core import GeneralLPBatch
    g = GeneralLPBatch.from_arrays(
        A=[[[1.0, 0.0]]], sense=["L"], rhs=[[4.0]],
        ub=[[np.inf, 7.0]], c=[[1.0, 0.0]], col_names=["X", "ZERO"],
        row_names=["R1"])
    out = str(tmp_path / "zerocol.mps")
    write_mps(g, out)
    g2 = read_mps(out)
    assert g2.n == 2 and g2.col_names == ("X", "ZERO")
    _equal(g, g2)


def test_write_rejects_batches():
    g = read_mps(fixture_path("testprob"))
    with pytest.raises(ValueError, match="one instance"):
        write_mps(perturbed_batch(g, 4), "/tmp/nope.mps")


# ---------------------------------------------------------------------------
# solving the fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,opt", [
    ("afiro", AFIRO_OPT), ("testprob", TESTPROB_OPT),
    ("sc50b_like", SC50B_LIKE_OPT), ("sc205_like", SC205_LIKE_OPT),
])
def test_fixture_optimum_oracle(name, opt):
    g = read_mps(fixture_path(name))
    res = solve_batched_reference(g)
    assert res.status[0] == OPTIMAL
    np.testing.assert_allclose(res.objective[0], opt, rtol=1e-9)
    assert general_violation(g, res.x)[0] < 1e-7


@pytest.mark.parametrize("backend", ["tableau", "revised"])
def test_fixture_f32_backends_agree(backend):
    for name, opt in (("afiro", AFIRO_OPT), ("sc50b_like", SC50B_LIKE_OPT)):
        g = read_mps(fixture_path(name))
        res = solve_batched_jax(g, backend=backend)
        assert res.status[0] == OPTIMAL, name
        np.testing.assert_allclose(res.objective[0], opt, rtol=1e-4)


def test_scaling_changes_f32_behavior_on_sc50b_like():
    """The f32 accuracy demo: the badly-scaled staircase solves cleanly
    with geometric-mean equilibration and falls apart without it."""
    g = read_mps(fixture_path("sc50b_like"))
    scaled = solve_batched_jax(g, scale=True)
    raw = solve_batched_jax(g, scale=False)
    assert scaled.status[0] == OPTIMAL
    np.testing.assert_allclose(scaled.objective[0], SC50B_LIKE_OPT, rtol=1e-4)
    degraded = (raw.status[0] != OPTIMAL
                or raw.iterations[0] != scaled.iterations[0]
                or abs(raw.objective[0] - SC50B_LIKE_OPT) > 1e-2)
    assert degraded, "unscaled f32 solve should differ measurably"


# ---------------------------------------------------------------------------
# perturbed batches (the paper's batch construction)
# ---------------------------------------------------------------------------

def test_perturbed_batch_structure_and_statuses():
    g = read_mps(fixture_path("afiro"))
    batch = perturbed_batch(g, 32, np.random.default_rng(7))
    assert batch.batch == 32
    np.testing.assert_array_equal(batch.A[0], g.A[0])   # member 0 untouched
    assert ((batch.A != 0) == (g.A[0] != 0)).all()      # sparsity preserved
    ref = solve_batched_reference(batch)
    assert (ref.status == OPTIMAL).mean() > 0.9
    jx = solve_batched(batch, backend="revised", pricing="partial")
    assert (jx.status == ref.status).mean() > 0.9
    ok = (ref.status == OPTIMAL) & (jx.status == OPTIMAL)
    rel = np.abs(jx.objective[ok] - ref.objective[ok]) \
        / np.abs(ref.objective[ok])
    assert rel.max() < 2e-3


def test_secondary_n_rows_ignored(tmp_path):
    """Legal MPS files may carry extra N (free) rows: the first is the
    objective, later ones are discarded along with their COLUMNS/RHS
    entries (real Netlib instances use them)."""
    src = open(fixture_path("testprob")).read()
    freed = src.replace(" N  COST\n", " N  COST\n N  FREEROW\n")
    freed = freed.replace(
        "    X1        COST            1.0   LIM1            1.0\n",
        "    X1        COST            1.0   LIM1            1.0\n"
        "    X1        FREEROW         2.0\n")
    freed = freed.replace(
        "    RHS1      MYEQN           7.0\n",
        "    RHS1      MYEQN           7.0   FREEROW         9.0\n")
    p = tmp_path / "freerows.mps"
    p.write_text(freed)
    g = read_mps(str(p))
    assert (g.m, g.n) == (3, 3)
    assert solve_batched_reference(g).objective[0] == TESTPROB_OPT


def test_markers_record_integrality(tmp_path):
    """INTORG/INTEND markers land in GeneralLPBatch.integer (no warning);
    the LP solvers still solve the continuous relaxation unchanged."""
    src = open(fixture_path("testprob")).read()
    marked = src.replace(
        "COLUMNS\n",
        "COLUMNS\n    M1        'MARKER'        'INTORG'\n")
    p = tmp_path / "marked.mps"
    p.write_text(marked)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        g = read_mps(str(p))
    assert not any("MARKER" in str(x.message) for x in w)
    assert g.integer is not None and g.integer.all()
    assert solve_batched_reference(g).objective[0] == TESTPROB_OPT


def test_integer_markers_round_trip(tmp_path):
    """A scattered integer mask survives write_mps -> read_mps (marker
    pairs per contiguous run), as do BV/UI-typed bounds."""
    src = open(fixture_path("testprob")).read()
    marked = src.replace(
        "    X2        COST",
        "    MARKER                 'MARKER'                 'INTORG'\n"
        "    X2        COST")
    marked = marked.replace(
        "    X3        COST",
        "    MARKER                 'MARKER'                 'INTEND'\n"
        "    X3        COST")
    p = tmp_path / "scattered.mps"
    p.write_text(marked)
    g = read_mps(str(p))
    assert g.integer is not None
    assert list(g.integer) == [False, True, False]
    q = tmp_path / "rt.mps"
    write_mps(g, str(q))
    g2 = read_mps(str(q))
    assert np.array_equal(g.integer, g2.integer)
    for field in ("A", "rhs", "c", "lb", "ub"):
        assert np.array_equal(getattr(g, field), getattr(g2, field))
