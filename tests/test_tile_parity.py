"""Interpret-mode parity: revised/PDHG tile kernels vs their JAX engines.

The contract these suites pin down (docs/architecture.md kernel table):
the revised tile kernel is *pivot-exact* against core/revised.py —
statuses and iteration counts identical, objectives to float32 rounding —
across pricing rules, warm starts and bounded columns; the PDHG segment
kernel reproduces solve_batched_pdhg_compacted's segment trajectory,
bucket shrinks included.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (OPTIMAL, random_lp_batch, solve_batched_pdhg,
                        solve_batched_pdhg_compacted, solve_batched_revised)
from repro.core.revised import REVISED_RULES
from repro.io.mps import fixture_path, read_mps
from repro.kernels import solve_batched_pallas

RNG = np.random.default_rng(23)


def _optimal_obj_close(ref, pal, rtol):
    ok = (ref.status == OPTIMAL) & (pal.status == OPTIMAL)
    assert ok.any()
    np.testing.assert_allclose(pal.objective[ok], ref.objective[ok],
                               rtol=rtol, atol=rtol)


# ---------------------------------------------------------------- revised

@pytest.mark.parametrize("pricing", REVISED_RULES)
@pytest.mark.parametrize("m,n", [(5, 5), (12, 8)])
@pytest.mark.parametrize("feas", [True, False])
def test_revised_tile_parity_sweep(pricing, m, n, feas):
    batch = random_lp_batch(RNG, B=17, m=m, n=n, feasible_start=feas)
    ref = solve_batched_revised(batch, pricing=pricing)
    pal = solve_batched_pallas(batch, backend="revised", tile_b=8,
                               pricing=pricing)
    np.testing.assert_array_equal(ref.status, pal.status)
    np.testing.assert_array_equal(ref.iterations, pal.iterations)
    _optimal_obj_close(ref, pal, 1e-4)


def test_revised_tile_warm_start_parity():
    batch = random_lp_batch(RNG, B=9, m=8, n=6)
    cold = solve_batched_revised(batch)
    warm = cold.warm_start()
    ref = solve_batched_revised(batch, warm=warm)
    pal = solve_batched_pallas(batch, backend="revised", tile_b=4, warm=warm)
    np.testing.assert_array_equal(ref.status, pal.status)
    np.testing.assert_array_equal(ref.iterations, pal.iterations)
    # a re-solve from the optimal basis must be (near-)free on both paths
    assert int(np.max(pal.iterations)) <= int(np.max(cold.iterations))
    _optimal_obj_close(ref, pal, 1e-4)


def test_revised_tile_bounded_columns_parity():
    base = random_lp_batch(RNG, B=11, m=6, n=5)
    ub = RNG.uniform(0.2, 1.5, size=(base.batch, base.n)).astype(np.float32)
    ub[:, ::2] = np.inf  # mix bounded and free-above columns
    batch = dataclasses.replace(base, ub=ub)
    ref = solve_batched_revised(batch)
    pal = solve_batched_pallas(batch, backend="revised", tile_b=8)
    np.testing.assert_array_equal(ref.status, pal.status)
    np.testing.assert_array_equal(ref.iterations, pal.iterations)
    _optimal_obj_close(ref, pal, 1e-4)


def test_revised_tile_mps_afiro():
    g = read_mps(fixture_path("afiro"))
    pal = solve_batched_pallas(g, backend="revised", tile_b=1)
    assert pal.status[0] == OPTIMAL
    np.testing.assert_allclose(pal.objective[0], -464.7531, rtol=1e-4)


def test_revised_tile_compaction_matches_engine():
    batch = random_lp_batch(RNG, B=24, m=6, n=6)
    ref = solve_batched_revised(batch)
    stats = []
    pal = solve_batched_pallas(batch, backend="revised", tile_b=8,
                               compaction=True, segment_k=6,
                               stats_out=stats)
    np.testing.assert_array_equal(ref.status, pal.status)
    _optimal_obj_close(ref, pal, 1e-3)
    assert stats, "compaction path must record segment stats"
    buckets = [s.bucket for s in stats]
    assert min(buckets) < max(buckets), "expected at least one bucket shrink"


# ------------------------------------------------------------------ pdhg

def test_pdhg_segment_kernel_matches_compacted_with_shrink():
    batch = random_lp_batch(RNG, B=24, m=5, n=5)
    stats_ref, stats_pal = [], []
    ref = solve_batched_pdhg_compacted(batch, segment_k=4,
                                       stats_out=stats_ref)
    pal = solve_batched_pallas(batch, backend="pdhg", tile_b=8,
                               compaction=True, segment_k=4,
                               stats_out=stats_pal)
    np.testing.assert_array_equal(ref.status, pal.status)
    _optimal_obj_close(ref, pal, 1e-3)
    # the bucket-shrink round trip: iterates survive at least one gather
    # into a smaller bucket and the solve still terminates correctly
    buckets = [s.bucket for s in stats_pal]
    assert min(buckets) < max(buckets), "expected at least one bucket shrink"
    # the kernel path walks the engine's bucket ladder, clipped below at
    # tile_b (the Pallas backend pads every bucket to a tile multiple)
    assert sorted(set(buckets)) == sorted(
        {max(s.bucket, 8) for s in stats_ref})


def test_pdhg_segment_kernel_monolithic_agreement():
    # whole-solve kernel vs engine: same restart logic, f32-fusion drift only
    batch = random_lp_batch(RNG, B=12, m=6, n=6)
    ref = solve_batched_pdhg(batch)
    pal = solve_batched_pallas(batch, backend="pdhg", tile_b=8)
    np.testing.assert_array_equal(ref.status, pal.status)
    _optimal_obj_close(ref, pal, 1e-3)
