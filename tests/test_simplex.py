"""Batched simplex vs the float64 NumPy oracle (the GLPK stand-in)."""
import numpy as np
import pytest

from repro.core import (LPBatch, OPTIMAL, UNBOUNDED, INFEASIBLE,
                        random_lp_batch, random_sparse_lp_batch,
                        solve_batched, solve_batched_jax,
                        solve_batched_reference, max_chunk_size)

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("m,n,feas", [
    (5, 5, True), (5, 5, False), (12, 8, True), (12, 8, False),
    (28, 28, True), (50, 40, True), (50, 40, False), (97, 71, True),
])
def test_matches_oracle(m, n, feas):
    batch = random_lp_batch(RNG, B=24, m=m, n=n, feasible_start=feas)
    ref = solve_batched_reference(batch)
    jx = solve_batched_jax(batch)
    assert (ref.status == jx.status).mean() >= 0.95
    ok = (ref.status == OPTIMAL) & (jx.status == OPTIMAL)
    assert ok.sum() > 0
    rel = np.abs(ref.objective[ok] - jx.objective[ok]) / np.abs(ref.objective[ok])
    assert rel.max() < 2e-3


def test_sparse_netlib_like():
    batch = random_sparse_lp_batch(RNG, B=16, m=71, n=97, density=0.08)
    ref = solve_batched_reference(batch)
    jx = solve_batched_jax(batch)
    ok = (ref.status == OPTIMAL) & (jx.status == OPTIMAL)
    rel = np.abs(ref.objective[ok] - jx.objective[ok]) / np.maximum(
        1.0, np.abs(ref.objective[ok]))
    assert rel.max() < 2e-3


def test_unbounded_detection():
    # maximize x1 with only a constraint on x2: unbounded
    A = np.array([[[0.0, 1.0]]])
    b = np.array([[1.0]])
    c = np.array([[1.0, 0.0]])
    batch = LPBatch.from_arrays(A, b, c)
    assert solve_batched_reference(batch).status[0] == UNBOUNDED
    assert solve_batched_jax(batch).status[0] == UNBOUNDED


def test_infeasible_detection():
    # x1 <= -1 with x1 >= 0: infeasible
    A = np.array([[[1.0]]])
    b = np.array([[-1.0]])
    c = np.array([[1.0]])
    batch = LPBatch.from_arrays(A, b, c)
    assert solve_batched_reference(batch).status[0] == INFEASIBLE
    assert solve_batched_jax(batch).status[0] == INFEASIBLE


def test_known_solution():
    # max x+y st x<=2, y<=3, x+y<=4  -> 4 at e.g. (1,3)
    A = np.array([[[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]]])
    b = np.array([[2.0, 3.0, 4.0]])
    c = np.array([[1.0, 1.0]])
    res = solve_batched_jax(LPBatch.from_arrays(A, b, c))
    assert res.status[0] == OPTIMAL
    np.testing.assert_allclose(res.objective[0], 4.0, rtol=1e-5)


def test_chunked_driver_matches():
    batch = random_lp_batch(RNG, B=64, m=10, n=6)
    full = solve_batched_jax(batch)
    chunked = solve_batched(batch, chunk_size=17)
    np.testing.assert_array_equal(full.status, chunked.status)
    ok = full.status == OPTIMAL
    np.testing.assert_allclose(full.objective[ok], chunked.objective[ok],
                               rtol=1e-6)


def test_memory_planning_eq5():
    batch = random_lp_batch(RNG, B=4, m=10, n=6)
    n1 = max_chunk_size(batch, device_bytes=1 << 20)
    n2 = max_chunk_size(batch, device_bytes=1 << 22)
    assert n2 == 4 * n1 or abs(n2 - 4 * n1) <= 3  # linear in memory (Eq. 5)
    assert max_chunk_size(batch, device_bytes=1 << 30, n_devices=2) \
        == 2 * max_chunk_size(batch, device_bytes=1 << 30, n_devices=1)


def test_solution_feasibility():
    batch = random_lp_batch(RNG, B=32, m=12, n=8, feasible_start=False)
    res = solve_batched_jax(batch)
    ok = res.status == OPTIMAL
    act = np.einsum("bmn,bn->bm", np.abs(batch.A), np.abs(res.x)) \
        + np.abs(batch.b) + 1.0
    viol = (np.einsum("bmn,bn->bm", batch.A, res.x) - batch.b) / act
    assert viol[ok].max() <= 2e-4
    assert res.x[ok].min() >= -1e-5


def test_sorted_batching_matches_unsorted():
    rng = np.random.default_rng(21)
    f = random_lp_batch(rng, B=40, m=10, n=8, feasible_start=True)
    i = random_lp_batch(rng, B=40, m=10, n=8, feasible_start=False)
    mixed = LPBatch(A=np.concatenate([f.A, i.A]),
                    b=np.concatenate([f.b, i.b]),
                    c=np.concatenate([f.c, i.c]))
    perm = rng.permutation(80)
    mixed = LPBatch(A=mixed.A[perm], b=mixed.b[perm], c=mixed.c[perm])
    plain = solve_batched(mixed, chunk_size=16)
    srt = solve_batched(mixed, chunk_size=16, sort_by_difficulty=True)
    np.testing.assert_array_equal(plain.status, srt.status)
    ok = plain.status == OPTIMAL
    np.testing.assert_allclose(plain.objective[ok], srt.objective[ok],
                               rtol=1e-5)
