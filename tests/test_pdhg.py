"""Restarted-PDHG backend: oracle/scipy cross-checks, certificates,
restart/tolerance properties, compaction round-trip, Pallas parity, and
fixture-level three-backend agreement.

The first-order engine is *tolerance-based* (core/lp.py
``backend_spec("pdhg").exact is False``): statuses must agree with the
exact oracles at the configured tolerance and objectives to ~tol relative
— never bitwise.  Tolerances below are chosen a decade above the solver
tolerance so the tests pin behavior, not float noise.
"""
import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import Mesh

import jax
from repro.core import (
    GeneralLPBatch, INFEASIBLE, LPBatch, OPTIMAL, UNBOUNDED,
    backend_spec, canonicalize, general_kkt, general_violation,
    solve_batched, solve_batched_compacted, solve_batched_jax,
    solve_batched_pdhg, solve_batched_pdhg_compacted,
    solve_batched_reference,
)
from repro.core.forms import LE
from repro.core.lp import canonicalize_backend
from repro.core.pdhg import kkt_residuals as _kkt_state  # noqa: F401 (api)
from repro.core.reference import random_lp_batch, random_sparse_lp_batch
from repro.io.mps import fixture_path, perturbed_batch, read_mps
from repro.kernels.ops import solve_batched_pallas

TOL = 1e-5          # the engine's f32 default
CHECK = 1e-3        # assertion budget: ~2 decades above TOL
# Cross-executor agreement budget: two different compilations (jit vs
# segment-jit vs pjit) of the same rounds fuse differently in f32, so the
# restart trajectories — and the tol-satisfying points they stop at — drift
# apart by ~feasibility-slack x multiplier scale.  1e-3 relative is the
# honest contract for a tolerance-based engine (cf. the revised backend's
# batch-decomposition note in core/revised.py).
XTOL = 1e-3


def _rng(k: int) -> np.random.Generator:
    """Per-test generators: no shared module state, no order dependence."""
    return np.random.default_rng(k)


def _rel_obj_err(res, ref):
    ok = (np.asarray(res.status) == OPTIMAL) & (np.asarray(ref.status) == OPTIMAL)
    assert ok.any()
    return (np.abs(res.objective[ok] - ref.objective[ok])
            / np.maximum(np.abs(ref.objective[ok]), 1e-12)).max()


def _canonical_kkt(batch: LPBatch, res):
    """Backend-independent certificate on a canonical batch: primal/dual
    feasibility + duality gap of (x, y), relative."""
    ok = np.asarray(res.status) == OPTIMAL
    A = np.asarray(batch.A, np.float64)[ok]
    b = np.asarray(batch.b, np.float64)[ok]
    c = np.asarray(batch.c, np.float64)[ok]
    x = np.asarray(res.x, np.float64)[ok]
    y = np.asarray(res.y, np.float64)[ok]
    rp = np.maximum(np.einsum("bmn,bn->bm", A, x) - b, 0.0).max(axis=1) \
        / (1.0 + np.abs(b).max(axis=1))
    rd = np.maximum(c - np.einsum("bmn,bm->bn", A, y), 0.0).max(axis=1) \
        / (1.0 + np.abs(c).max(axis=1))
    p = np.einsum("bn,bn->b", c, x)
    d = np.einsum("bm,bm->b", b, y)
    gap = np.abs(p - d) / (1.0 + np.abs(p) + np.abs(d))
    return np.maximum(np.maximum(rp, rd), gap).max()


# ---------------------------------------------------------------------------
# oracle / scipy cross-checks
# ---------------------------------------------------------------------------

def test_dense_matches_oracle():
    batch = random_lp_batch(_rng(0), 16, 10, 10)
    ref = solve_batched_reference(batch)
    res = solve_batched_pdhg(batch)
    assert (res.status == ref.status).all()
    assert _rel_obj_err(res, ref) < CHECK
    assert _canonical_kkt(batch, res) < 10 * TOL


def test_dense_phase1_class_matches_oracle():
    batch = random_lp_batch(_rng(1), 16, 12, 12, feasible_start=False)
    ref = solve_batched_reference(batch)
    res = solve_batched_pdhg(batch)
    assert (res.status == ref.status).mean() >= 0.9
    assert _rel_obj_err(res, ref) < CHECK


def test_sparse_matches_oracle():
    batch = random_sparse_lp_batch(_rng(2), 16, 12, 16)
    ref = solve_batched_reference(batch)
    res = solve_batched_pdhg(batch)
    assert (res.status == ref.status).mean() >= 0.9
    assert _rel_obj_err(res, ref) < CHECK


def test_matches_scipy_on_general_min_problems():
    scipy_opt = pytest.importorskip("scipy.optimize")
    rng = np.random.default_rng(3)
    B, m, n = 6, 6, 5
    A = rng.uniform(-1.0, 2.0, size=(B, m, n))
    x0 = rng.uniform(0.5, 1.5, size=(B, n))
    rhs = np.einsum("bmn,bn->bm", A, x0) + rng.uniform(0.2, 1.0, size=(B, m))
    c = rng.uniform(0.2, 2.0, size=(B, n))      # bounded min: c > 0, x >= 0
    g = GeneralLPBatch.from_arrays(A, [LE] * m, rhs, c=c)
    res = solve_batched_pdhg(g)
    for k in range(B):
        sp = scipy_opt.linprog(c[k], A_ub=A[k], b_ub=rhs[k],
                               bounds=[(0, None)] * n, method="highs")
        assert res.status[k] == OPTIMAL and sp.status == 0
        assert abs(res.objective[k] - sp.fun) <= CHECK * (1 + abs(sp.fun))
        # dual certificate in scipy's (min) convention: row marginals <= 0
        np.testing.assert_allclose(res.y[k], sp.ineqlin.marginals,
                                   atol=5e-3, rtol=5e-3)


def test_degenerate_equality_batch():
    # equality rows canonicalize into <=-pairs — maximal degeneracy
    rng = np.random.default_rng(5)
    A = rng.uniform(-1.0, 1.0, size=(4, 3, 6))
    x0 = rng.uniform(0.2, 1.0, size=(4, 6))
    rhs = np.einsum("bmn,bn->bm", A, x0)
    c = rng.uniform(0.1, 1.0, size=(4, 6))
    g = GeneralLPBatch.from_arrays(A, ["E", "E", "L"], rhs, c=c,
                                   ub=np.full((4, 6), 3.0))
    ref = solve_batched_reference(g)
    res = solve_batched_pdhg(g)
    assert (res.status == ref.status).all()
    assert _rel_obj_err(res, ref) < CHECK
    assert general_violation(g, res.x)[res.status == OPTIMAL].max() < 1e-2


def test_infeasible_detected():
    # x1 + x2 <= -1 with x >= 0 is a clean Farkas certificate
    A = np.tile(np.array([[[1.0, 1.0], [-1.0, -1.0]]]), (4, 1, 1))
    b = np.tile(np.array([[-1.0, -2.0]]), (4, 1))
    c = np.ones((4, 2))
    res = solve_batched_pdhg(LPBatch.from_arrays(A, b, c))
    assert (res.status == INFEASIBLE).all()


def test_unbounded_detected():
    # max x1 with only -x1 <= 1: the primal ray is x1 -> inf
    A = np.tile(np.array([[[-1.0, 0.0]]]), (4, 1, 1))
    b = np.ones((4, 1))
    c = np.tile(np.array([[1.0, 0.0]]), (4, 1))
    res = solve_batched_pdhg(LPBatch.from_arrays(A, b, c))
    assert (res.status == UNBOUNDED).all()


# ---------------------------------------------------------------------------
# solver properties
# ---------------------------------------------------------------------------

def test_restart_invariance_of_certificates():
    # the check cadence changes restart timing and therefore the iterate
    # path, but never the certificate: statuses agree, objectives to ~tol
    batch = random_lp_batch(_rng(10), 8, 8, 8)
    a = solve_batched_pdhg(batch, check_every=8)
    b = solve_batched_pdhg(batch, check_every=32)
    assert (a.status == b.status).all()
    ok = a.status == OPTIMAL
    np.testing.assert_allclose(a.objective[ok], b.objective[ok], rtol=1e-3)


def test_tolerance_monotonicity():
    batch = random_lp_batch(_rng(11), 8, 8, 8)
    ref = solve_batched_reference(batch)
    errs = []
    for tol in (1e-2, 1e-3, 1e-5):
        res = solve_batched_pdhg(batch, tol=tol)
        assert (res.status == OPTIMAL).all()
        errs.append(_rel_obj_err(res, ref))
    # tightening the tolerance can only improve the objective (with slack
    # for the quantized check cadence)
    assert errs[2] <= errs[0] + 1e-6
    assert errs[2] < 10 * TOL


def test_iterations_count_and_cap():
    batch = random_lp_batch(_rng(12), 4, 6, 6)
    res = solve_batched_pdhg(batch, max_iters=64)
    # the cap quantizes to check rounds and binds
    assert (res.iterations <= 64).all()
    capped = solve_batched_pdhg(batch, tol=1e-12, max_iters=64)
    from repro.core import ITERATION_LIMIT
    assert (capped.status == ITERATION_LIMIT).all()


# ---------------------------------------------------------------------------
# composition: compaction, chunked driver, distributed, Pallas
# ---------------------------------------------------------------------------

def test_compaction_round_trip():
    batch = random_lp_batch(_rng(13), 24, 8, 8)
    mono = solve_batched_pdhg(batch)
    stats = []
    sched = solve_batched_pdhg_compacted(batch, segment_k=4,
                                         compact_threshold=0.75,
                                         stats_out=stats)
    assert (sched.status == mono.status).all()
    ok = mono.status == OPTIMAL
    np.testing.assert_allclose(sched.objective[ok], mono.objective[ok],
                               rtol=XTOL, atol=XTOL)
    # the bucket ladder actually shrank (PDHG iteration spread is wide)
    buckets = {s.bucket for s in stats}
    assert len(buckets) > 1 and min(buckets) < 24
    # duals survive the gather/flush path
    assert np.isfinite(sched.y[ok]).all()


def test_backend_kwarg_on_compacted_entry():
    batch = random_lp_batch(_rng(14), 8, 6, 6)
    a = solve_batched_compacted(batch, backend="pdhg")
    b = solve_batched_pdhg_compacted(batch)
    assert (a.status == b.status).all()


def test_chunked_driver_and_sorting():
    batch = random_lp_batch(_rng(15), 12, 6, 6)
    res = solve_batched(batch, backend="pdhg", chunk_size=5,
                        sort_by_difficulty=True)
    mono = solve_batched_pdhg(batch)
    assert (res.status == mono.status).all()
    ok = mono.status == OPTIMAL
    np.testing.assert_allclose(res.objective[ok], mono.objective[ok],
                               rtol=XTOL, atol=XTOL)
    assert res.y is not None and res.y.shape == (12, 6)


def test_distributed_entry_points():
    from repro.core import solve_pjit, solve_shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    batch = random_lp_batch(_rng(16), 6, 6, 6)
    mono = solve_batched_pdhg(batch)
    pj = solve_pjit(batch, mesh, backend="pdhg")
    sm = solve_shard_map(batch, mesh, backend="pdhg")
    seg = solve_shard_map(batch, mesh, backend="pdhg", segment_k=8)
    for r in (pj, sm, seg):
        assert (r.status == mono.status).all()
        ok = mono.status == OPTIMAL
        np.testing.assert_allclose(r.objective[ok], mono.objective[ok],
                                   rtol=XTOL, atol=XTOL)
    assert pj.y is not None and seg.y is not None


def test_pallas_interpret_parity():
    batch = random_lp_batch(_rng(17), 10, 8, 8)
    mono = solve_batched_pdhg(batch)
    pk = solve_batched_pallas(batch, backend="pdhg", tile_b=4)
    assert (pk.status == mono.status).all()
    ok = mono.status == OPTIMAL
    np.testing.assert_allclose(pk.objective[ok], mono.objective[ok],
                               rtol=1e-4, atol=1e-4)
    # the kernel emits the same certificate
    assert _canonical_kkt(batch, pk) < 10 * TOL


# ---------------------------------------------------------------------------
# fixtures: three-backend agreement + original-space certificates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["afiro", "sc50b_like"])
def test_fixture_three_backend_agreement(fixture):
    g = read_mps(fixture_path(fixture))
    gb = perturbed_batch(g, 8, np.random.default_rng(0))
    ref = solve_batched_reference(gb)
    results = {b: solve_batched_jax(gb, backend=b)
               for b in ("tableau", "revised", "pdhg")}
    for name, res in results.items():
        assert (res.status == ref.status).all(), \
            f"{name} status parity failed on {fixture}"
        assert _rel_obj_err(res, ref) < 1e-4, name
    # all three emit an original-coordinate dual certificate
    for name, res in results.items():
        ok = res.status == OPTIMAL
        kkt = general_kkt(gb, res.x, res.y, res.z)
        scale = 1.0 + np.abs(gb.rhs).max() + np.abs(gb.c).max()
        assert kkt["max"][ok].max() < 5e-3 * scale, \
            f"{name} KKT violation on {fixture}: {kkt['max'][ok].max()}"


def test_fixture_pdhg_through_every_entry_point():
    g = read_mps(fixture_path("afiro"))
    gb = perturbed_batch(g, 4, np.random.default_rng(1))
    ref = solve_batched_reference(gb)
    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    from repro.core import solve_pjit, solve_shard_map

    paths = {
        "jax": solve_batched_jax(gb, backend="pdhg"),
        "batched": solve_batched(gb, backend="pdhg"),
        "compacted": solve_batched_compacted(gb, backend="pdhg"),
        "pjit": solve_pjit(gb, mesh, backend="pdhg"),
        "shard_map": solve_shard_map(gb, mesh, backend="pdhg"),
        "pallas": solve_batched_pallas(gb, backend="pdhg"),
    }
    for name, res in paths.items():
        assert (res.status == ref.status).all(), name
        assert _rel_obj_err(res, ref) < 1e-4, name


# ---------------------------------------------------------------------------
# dual certificates are backend-uniform (simplex engines included)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["tableau", "revised", "pdhg"])
def test_canonical_duals_all_backends(backend):
    batch = random_lp_batch(_rng(18), 8, 8, 8)
    res = solve_batched_jax(batch, backend=backend)
    ok = res.status == OPTIMAL
    assert ok.any() and res.y is not None and res.z is not None
    assert _canonical_kkt(batch, res) < 1e-3
    # z is definitionally c - A^T y (up to f32 matvec noise)
    z_chk = np.asarray(batch.c) - np.einsum("bmn,bm->bn",
                                            np.asarray(batch.A), res.y)
    np.testing.assert_allclose(res.z[ok], z_chk[ok], rtol=1e-3, atol=1e-2)
    # duals are NaN off-OPTIMAL
    bad = ~ok
    if bad.any():
        assert np.isnan(res.y[bad]).all()


def test_oracle_emits_duals():
    batch = random_lp_batch(_rng(19), 6, 6, 6)
    ref = solve_batched_reference(batch)
    assert ref.y is not None
    assert _canonical_kkt(batch, ref) < 1e-9


def test_recovered_duals_follow_min_convention():
    scipy_opt = pytest.importorskip("scipy.optimize")
    rng = np.random.default_rng(9)
    B, m, n = 4, 5, 4
    A = rng.uniform(-1.0, 2.0, size=(B, m, n))
    x0 = rng.uniform(0.5, 1.5, size=(B, n))
    rhs = np.einsum("bmn,bn->bm", A, x0) + rng.uniform(0.2, 1.0, size=(B, m))
    c = rng.uniform(0.2, 2.0, size=(B, n))
    g = GeneralLPBatch.from_arrays(A, [LE] * m, rhs, c=c)
    for backend in ("tableau", "revised"):
        res = solve_batched_jax(g, backend=backend)
        for k in range(B):
            sp = scipy_opt.linprog(c[k], A_ub=A[k], b_ub=rhs[k],
                                   bounds=[(0, None)] * n, method="highs")
            assert res.status[k] == OPTIMAL and sp.status == 0
            np.testing.assert_allclose(res.y[k], sp.ineqlin.marginals,
                                       atol=5e-4, rtol=5e-3)
            np.testing.assert_allclose(res.z[k], sp.lower.marginals,
                                       atol=5e-4, rtol=5e-3)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_capabilities():
    assert canonicalize_backend("pdhg") == "pdhg"
    with pytest.raises(ValueError, match="unknown backend"):
        canonicalize_backend("simplex")
    assert backend_spec("tableau").exact
    assert backend_spec("revised").exact
    assert not backend_spec("pdhg").exact
    assert backend_spec("pdhg").supports_pallas
    assert backend_spec("revised").supports_pallas


def test_pdhg_rejects_pricing_rules():
    batch = random_lp_batch(_rng(20), 2, 4, 4)
    with pytest.raises(ValueError, match="pricing"):
        solve_batched_pdhg(batch, pricing="devex")
    with pytest.raises(ValueError, match="pricing"):
        solve_batched_jax(batch, backend="pdhg", pricing="steepest_edge")
