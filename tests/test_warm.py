"""Warm-start engine invariants (core/lp.py WarmStart, ``warm=`` on every
solve_*).

Warm starts change the *path*, never the *answer*: for every engine and
pricing rule a warm re-solve of a perturbed trajectory must agree with the
cold solve on statuses and objectives while doing no more work; broken,
stale, or mis-shaped carriers must degrade to a cold solve per LP (not to
wrong answers); and the chunked driver must make warm solving invisible —
chunked warm results equal unchunked ones bit-identically, through
difficulty sorting and re-permutation.
"""
import numpy as np
import pytest

from repro.core import (
    INFEASIBLE,
    OPTIMAL,
    PRICING_RULES,
    LPBatch,
    WarmStart,
    random_lp_batch,
    solve_batched,
    solve_batched_compacted,
    solve_batched_jax,
    solve_batched_pdhg,
    solve_batched_reference,
    solve_batched_revised,
)
from repro.io.mps import fixture_path, perturbed_sequence, read_mps

REVISED_RULES = ("dantzig", "partial")


def _afiro_seq(B=8, K=3, seed=0, **kw):
    g = read_mps(fixture_path("afiro"))
    return perturbed_sequence(g, B, K, np.random.default_rng(seed), **kw)


def _assert_same_answers(cold, warm, rtol=2e-3):
    np.testing.assert_array_equal(cold.status, warm.status)
    ok = np.asarray(cold.status) == OPTIMAL
    np.testing.assert_allclose(np.asarray(warm.objective)[ok],
                               np.asarray(cold.objective)[ok], rtol=rtol)


# ---------------------------------------------------------------------------
# perturbed trajectories: every engine x pricing rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", PRICING_RULES)
def test_tableau_warm_trajectory(rule):
    """Chained warm re-solves of a nudged AFIRO batch: same certificates,
    strictly less pivot work than cold (the parent basis is optimal or a
    repair step away)."""
    seq = _afiro_seq()
    ws, cold_tot, warm_tot = None, 0, 0
    for k, gb in enumerate(seq):
        cold = solve_batched_jax(gb, pricing=rule)
        if k > 0:
            warm = solve_batched_jax(gb, pricing=rule, warm=ws)
            _assert_same_answers(cold, warm)
            cold_tot += int(cold.iterations.astype(np.int64).sum())
            warm_tot += int(warm.iterations.astype(np.int64).sum())
            ws = warm.warm_start()
        else:
            ws = cold.warm_start()
    assert warm_tot < cold_tot, (warm_tot, cold_tot)


@pytest.mark.parametrize("rule", REVISED_RULES)
def test_revised_warm_trajectory(rule):
    seq = _afiro_seq(seed=1)
    ws, cold_tot, warm_tot = None, 0, 0
    for k, gb in enumerate(seq):
        cold = solve_batched_revised(gb, pricing=rule)
        if k > 0:
            warm = solve_batched_revised(gb, pricing=rule, warm=ws)
            _assert_same_answers(cold, warm)
            cold_tot += int(cold.iterations.astype(np.int64).sum())
            warm_tot += int(warm.iterations.astype(np.int64).sum())
            ws = warm.warm_start()
        else:
            ws = cold.warm_start()
    assert warm_tot < cold_tot, (warm_tot, cold_tot)


def test_pdhg_warm_trajectory():
    """The first-order engine resumes from the parent's iterates and primal
    weight; the residual guard makes adoption monotone, so warm iteration
    counts drop while the tolerance-based answers agree with cold."""
    seq = _afiro_seq(seed=2)
    ws, cold_tot, warm_tot = None, 0, 0
    for k, gb in enumerate(seq):
        cold = solve_batched_pdhg(gb)
        if k > 0:
            warm = solve_batched_pdhg(gb, warm=ws)
            _assert_same_answers(cold, warm)
            cold_tot += int(cold.iterations.astype(np.int64).sum())
            warm_tot += int(warm.iterations.astype(np.int64).sum())
            ws = warm.warm_start()
        else:
            ws = cold.warm_start()
    assert warm_tot < cold_tot, (warm_tot, cold_tot)


@pytest.mark.parametrize("fixture,backend", [
    ("sc50b_like", "tableau"), ("sc50b_like", "revised"),
    ("sc50b_like", "pdhg"),
    ("sc205_like", "tableau"),
    # sc205_like x revised is excluded: the f32 revised engine already hits
    # the iteration cap on half the COLD batch there (a pre-existing
    # capability edge, not a warm-start property), so there is no reliable
    # cold reference to require bit-parity against — warm starts actually
    # rescue some of the capped LPs while a degenerate one stalls.
])
def test_staircase_fixture_trajectories(fixture, backend):
    """The ill-scaled staircase fixtures (equalities, RANGES, bounded
    columns): warm answers must match cold through canonicalization +
    equilibration, with no more work."""
    g = read_mps(fixture_path(fixture))
    seq = perturbed_sequence(g, 4, 2, np.random.default_rng(13))
    ws = solve_batched(seq[0], backend=backend).warm_start()
    cold = solve_batched(seq[1], backend=backend)
    warm = solve_batched(seq[1], backend=backend, warm=ws)
    _assert_same_answers(cold, warm)
    assert warm.iterations.astype(np.int64).sum() \
        <= cold.iterations.astype(np.int64).sum()


def test_sign_flip_rhs_edit_uses_repair_path():
    """A sign-flipping rhs edit makes the parent basis primal-infeasible on
    the flipped rows: the injection must re-seed artificials there (the
    bounded repair pass) and still land on the cold certificates."""
    rng = np.random.default_rng(14)
    batch = random_lp_batch(rng, 12, 8, 6, feasible_start=True)
    parent = solve_batched_jax(batch)
    b2 = np.asarray(batch.b).copy()
    b2[:, ::2] *= -1.0
    edited = LPBatch(A=batch.A, b=b2, c=batch.c)
    cold = solve_batched_jax(edited)
    warm = solve_batched_jax(edited, warm=parent.warm_start())
    _assert_same_answers(cold, warm, rtol=1e-4)


def test_cross_engine_carrier():
    """The carrier is backend-uniform: a tableau parent seeds the revised
    engine and the f64 oracle (and back) — the basis leaves mean the same
    thing everywhere."""
    seq = _afiro_seq(K=2, seed=3)
    parent = solve_batched_jax(seq[0])
    ws = parent.warm_start()
    for solver in (solve_batched_revised, solve_batched_reference):
        cold = solver(seq[1])
        warm = solver(seq[1], warm=ws)
        _assert_same_answers(cold, warm)
        assert warm.iterations.astype(np.int64).sum() \
            <= cold.iterations.astype(np.int64).sum()
    # and the oracle's terminal state seeds the f32 tableau engine
    oref = solve_batched_reference(seq[1])
    back = solve_batched_jax(seq[1], warm=oref.warm_start())
    _assert_same_answers(solve_batched_jax(seq[1]), back)


# ---------------------------------------------------------------------------
# the chunked driver: warm solving must be invisible to chunking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("tableau", "revised", "pdhg"))
def test_chunked_warm_equals_unchunked(backend):
    seq = _afiro_seq(B=12, K=2, seed=4)
    ws = solve_batched(seq[0], backend=backend).warm_start()
    full = solve_batched(seq[1], backend=backend, warm=ws)
    chunked = solve_batched(seq[1], backend=backend, warm=ws, chunk_size=5)
    sorted_ = solve_batched(seq[1], backend=backend, warm=ws, chunk_size=5,
                            sort_by_difficulty=True)
    for other in (chunked, sorted_):
        np.testing.assert_array_equal(full.status, other.status)
        np.testing.assert_array_equal(full.iterations, other.iterations)
        np.testing.assert_array_equal(full.objective, other.objective)
    # the terminal carrier survives concatenation/unpermutation: chaining
    # from the chunked result equals chaining from the unchunked one
    assert chunked.warm is not None and sorted_.warm is not None
    nxt_full = solve_batched(seq[1], backend=backend, warm=full.warm_start())
    nxt_chunk = solve_batched(seq[1], backend=backend,
                              warm=sorted_.warm_start())
    np.testing.assert_array_equal(nxt_full.status, nxt_chunk.status)
    np.testing.assert_array_equal(nxt_full.iterations, nxt_chunk.iterations)


# ---------------------------------------------------------------------------
# adversarial carriers: repair or fall back to cold, never a wrong answer
# ---------------------------------------------------------------------------

def test_garbage_basis_degrades_to_cold_answers():
    """A syntactically valid but nonsensical basis (duplicates, wrong
    columns) must be repaired or dropped per LP — certificates unchanged."""
    rng = np.random.default_rng(5)
    batch = random_lp_batch(rng, 12, 8, 6, feasible_start=False)
    m, n, B = 8, 6, 12
    garbage = WarmStart(
        m=m, n=n,
        basis=rng.integers(0, n + m, size=(B, m)).astype(np.int32),
        at_upper=np.zeros((B, n), bool))
    for solver in (solve_batched_jax, solve_batched_revised,
                   solve_batched_reference):
        cold = solver(batch)
        warm = solver(batch, warm=garbage)
        _assert_same_answers(cold, warm, rtol=1e-4)


def test_garbage_iterates_trip_pdhg_reset_guard():
    """Iterates far worse than the cold start must be rejected by the
    residual guard: the warm solve IS the cold solve, bit for bit."""
    rng = np.random.default_rng(6)
    batch = random_lp_batch(rng, 8, 6, 5)
    m, n, B = 6, 5, 8
    garbage = WarmStart(
        m=m, n=n,
        x=np.full((B, n), 1e12), y=np.full((B, m), -1e12),
        omega=np.full((B,), 1e9), eta=np.full((B,), 1.0))
    cold = solve_batched_pdhg(batch)
    warm = solve_batched_pdhg(batch, warm=garbage)
    np.testing.assert_array_equal(cold.status, warm.status)
    np.testing.assert_array_equal(cold.iterations, warm.iterations)
    np.testing.assert_array_equal(cold.objective, warm.objective)


def test_infeasible_parent_reuse():
    """Warm-starting from a parent whose LPs include INFEASIBLE ones keeps
    the infeasibility certificates on the re-solve."""
    rng = np.random.default_rng(7)
    batch = random_lp_batch(rng, 16, 8, 6, feasible_start=False)
    # make half the LPs provably infeasible: a nonnegative row with a
    # negative rhs cannot be satisfied by x >= 0
    A = np.asarray(batch.A).copy()
    b = np.asarray(batch.b).copy()
    A[::2, 0, :] = np.abs(A[::2, 0, :])
    b[::2, 0] = -1.0
    batch = LPBatch(A=A, b=b, c=batch.c)
    cold = solve_batched_jax(batch)
    assert (np.asarray(cold.status) == INFEASIBLE).any(), \
        "fixture drift: batch no longer contains infeasible LPs"
    warm = solve_batched_jax(batch, warm=cold.warm_start())
    _assert_same_answers(cold, warm, rtol=1e-4)
    assert warm.iterations.astype(np.int64).sum() \
        <= cold.iterations.astype(np.int64).sum()


def test_shape_mismatch_drops_to_cold_with_warning():
    seq = _afiro_seq(K=1, seed=8)
    other = read_mps(fixture_path("testprob"))
    ws = solve_batched_jax(seq[0]).warm_start()
    cold = solve_batched_jax(other)
    with pytest.warns(UserWarning, match="warm start dropped"):
        warm = solve_batched_jax(other, warm=ws)
    np.testing.assert_array_equal(cold.status, warm.status)
    np.testing.assert_array_equal(cold.iterations, warm.iterations)


def test_batch_mismatch_drops_to_cold_with_warning():
    seq = _afiro_seq(B=8, K=2, seed=9)
    ws = solve_batched_jax(seq[0]).warm_start()
    bigger = perturbed_sequence(read_mps(fixture_path("afiro")), 10, 1,
                                np.random.default_rng(9))[0]
    cold = solve_batched_jax(bigger)
    with pytest.warns(UserWarning, match="warm start dropped"):
        warm = solve_batched_jax(bigger, warm=ws)
    np.testing.assert_array_equal(cold.status, warm.status)
    np.testing.assert_array_equal(cold.iterations, warm.iterations)


# ---------------------------------------------------------------------------
# carrier plumbing
# ---------------------------------------------------------------------------

def test_warm_start_raises_without_state():
    rng = np.random.default_rng(10)
    batch = random_lp_batch(rng, 4, 5, 4)
    res = solve_batched_compacted(batch)  # compacted paths emit warm=None
    assert res.warm is None
    with pytest.raises(ValueError):
        res.warm_start()


def test_compacted_paths_accept_warm():
    """The compaction scheduler consumes a carrier (bucket gathers ride the
    generic state tree) even though it does not emit one."""
    seq = _afiro_seq(B=8, K=2, seed=11)
    ws = solve_batched_jax(seq[0]).warm_start()
    cold = solve_batched_compacted(seq[1])
    warm = solve_batched_compacted(seq[1], warm=ws)
    _assert_same_answers(cold, warm, rtol=1e-4)
    assert warm.iterations.astype(np.int64).sum() \
        <= cold.iterations.astype(np.int64).sum()


def test_carrier_take_slice_concat_roundtrip():
    seq = _afiro_seq(B=9, K=1, seed=12)
    ws = solve_batched_jax(seq[0]).warm_start()
    parts = [ws.slice(0, 4), ws.slice(4, 9)]
    back = WarmStart.concat(parts)
    np.testing.assert_array_equal(ws.basis, back.basis)
    np.testing.assert_array_equal(ws.at_upper, back.at_upper)
    perm = np.array([2, 0, 1, 5, 4, 3, 8, 7, 6])
    inv = np.empty_like(perm)
    inv[perm] = np.arange(9)
    np.testing.assert_array_equal(ws.take(perm).take(inv).basis, ws.basis)
    assert WarmStart.concat([parts[0], None]) is None
