"""Pallas SSM-scan kernel (hillclimb 4): exactness vs scan reference,
gradient parity, and model-level drop-in equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import ssm_scan_bt_ds

RNG = np.random.default_rng(0)


def _ref(dA, dBx, h0):
    def step(h, inp):
        a, b = inp
        h = a * h + b
        return h, h
    hT, hs = jax.lax.scan(step, h0, (jnp.moveaxis(dA, 1, 0),
                                     jnp.moveaxis(dBx, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hT


@pytest.mark.parametrize("B,T,d,s", [(1, 8, 8, 2), (2, 16, 24, 4),
                                     (2, 33, 130, 16), (3, 7, 256, 16)])
def test_forward_exact(B, T, d, s):
    dA = jnp.asarray(RNG.uniform(0.5, 1.0, (B, T, d, s)), jnp.float32)
    dBx = jnp.asarray(RNG.normal(size=(B, T, d, s)) * 0.1, jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, d, s)) * 0.1, jnp.float32)
    hs_r, hT_r = _ref(dA, dBx, h0)
    hs_k, hT_k = ssm_scan_bt_ds(dA, dBx, h0)
    np.testing.assert_allclose(hs_k, hs_r, atol=1e-6)
    np.testing.assert_allclose(hT_k, hT_r, atol=1e-6)


def test_gradients_match_reference():
    B, T, d, s = 2, 16, 24, 4
    dA = jnp.asarray(RNG.uniform(0.5, 1.0, (B, T, d, s)), jnp.float32)
    dBx = jnp.asarray(RNG.normal(size=(B, T, d, s)) * 0.1, jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, d, s)) * 0.1, jnp.float32)
    w = jnp.arange(1, T + 1, dtype=jnp.float32)[None, :, None, None]

    def loss(fn):
        def f(args):
            hs, hT = fn(*args)
            return (hs * w).sum() + (hT ** 2).sum()
        return f

    g_r = jax.grad(loss(_ref))((dA, dBx, h0))
    g_k = jax.grad(loss(ssm_scan_bt_ds))((dA, dBx, h0))
    for a, b in zip(g_r, g_k):
        np.testing.assert_allclose(b, a, atol=1e-5)


def test_model_level_drop_in():
    from repro.configs import get_config
    from repro.models import build_model
    base = get_config("falcon-mamba-7b").reduced()
    toks = jnp.asarray(RNG.integers(0, base.vocab, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    losses = {}
    grads = {}
    for impl in ("assoc", "kernel"):
        cfg = dataclasses.replace(base, ssm_impl=impl)
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        l, g = jax.value_and_grad(model.loss_fn)(params, batch)
        losses[impl], grads[impl] = float(l), g
    assert abs(losses["assoc"] - losses["kernel"]) < 1e-5
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(grads["assoc"]), jax.tree.leaves(grads["kernel"])))
    assert d < 1e-4
