"""Int8+EF gradient compression: quantizer properties and training parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataPipeline
from repro.distributed.compression import (compress_decompress, ef_init,
                                           make_compressed_train_step,
                                           quantize_int8)
from repro.distributed.steps import make_train_step
from repro.models import build_model
from repro.optim import get_optimizer


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256, 64)) * 3.0, jnp.float32)
    q, s = quantize_int8(g)
    assert q.dtype == jnp.int8
    err = jnp.abs(compress_decompress(g) - g)
    assert float(err.max()) <= float(s) / 2 + 1e-7  # half-ulp of the grid


def test_compressed_training_tracks_fp32():
    cfg = get_config("qwen3-32b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    data = DataPipeline(vocab=cfg.vocab, batch=8, seq=32, seed=0)
    opt = get_optimizer("adamw", lr=3e-3, warmup=10)

    full = jax.jit(make_train_step(model, opt))
    comp = jax.jit(make_compressed_train_step(model, opt))

    p1, o1 = params, opt.init(params)
    p2, o2, ef = params, opt.init(params), ef_init(params)
    l1s, l2s = [], []
    for s in range(25):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        p1, o1, m1 = full(p1, o1, b)
        p2, o2, ef, m2 = comp(p2, o2, ef, b)
        l1s.append(float(m1["loss"]))
        l2s.append(float(m2["loss"]))
    # both decrease, and the compressed trajectory tracks fp32 closely
    assert np.mean(l1s[-5:]) < np.mean(l1s[:5]) - 0.2
    assert np.mean(l2s[-5:]) < np.mean(l2s[:5]) - 0.2
    assert abs(np.mean(l2s[-5:]) - np.mean(l1s[-5:])) < 0.15, (l1s[-5:], l2s[-5:])


def test_error_feedback_carries_residual():
    rng = np.random.default_rng(1)
    grads = {"w": jnp.asarray(rng.normal(size=(32,)) * 1e-6, jnp.float32)}
    # tiny grads vanish under per-tensor int8 of a tensor with one big entry
    grads["w"] = grads["w"].at[0].set(1.0)
    from repro.distributed.compression import ef_compress_tree
    ef = {"w": jnp.zeros((32,), jnp.float32)}
    total = jnp.zeros((32,), jnp.float32)
    for _ in range(300):
        c, ef = ef_compress_tree(grads, ef)
        total = total + c["w"]
    # the accumulated compressed signal approximates the true accumulated
    # gradient — EF prevents the small coordinates from being silently lost
    true = grads["w"] * 300
    rel = float(jnp.linalg.norm(total - true) / jnp.linalg.norm(true))
    assert rel < 0.05, rel
