"""Doc-sync tests: the documentation layer must track the code it describes.

Three contracts, one per document:

* README.md's backend capability table matches ``BACKEND_REGISTRY``
  cell-by-cell — every registered backend has a row, and the row's
  exact/tolerance and yes/no cells agree with the registry flags;
* every ``solve_*`` entry point named in docs/architecture.md is a real
  attribute of ``repro.core`` (docs never name a function that does not
  exist);
* every top-level row-list section of BENCH_pivot_work.json has a matching
  ``### `section` `` heading in benchmarks/README.md, and vice versa.

These run in the tier-1 suite and in the CI ``docs`` leg, so a PR that
adds a backend, renames an entry point, or adds a benchmark section fails
until the docs move with it.
"""
import json
import re
from pathlib import Path

import pytest

from repro.core.lp import BACKEND_REGISTRY

REPO = Path(__file__).resolve().parent.parent
README = REPO / "README.md"
ARCHITECTURE = REPO / "docs" / "architecture.md"
BENCH_README = REPO / "benchmarks" / "README.md"
BENCH_JSON = REPO / "BENCH_pivot_work.json"


def _readme_backend_rows():
    """Parse README's capability table into {backend: [cell, ...]}."""
    rows = {}
    for line in README.read_text().splitlines():
        m = re.match(r"\|\s*`(\w+)`\s*\|(.*)\|\s*$", line)
        if m and m.group(1) in BACKEND_REGISTRY:
            cells = [c.strip().lower() for c in m.group(2).split("|")]
            rows[m.group(1)] = cells
    return rows


def test_readme_exists_with_required_sections():
    text = README.read_text()
    for needle in ("## Solver backends", "## Quickstart",
                   "python -m pytest -x -q", "scripts/check.sh",
                   "BENCH_pivot_work.json"):
        assert needle in text, f"README.md lost required content: {needle!r}"


def test_readme_backend_table_matches_registry():
    rows = _readme_backend_rows()
    missing = set(BACKEND_REGISTRY) - set(rows)
    assert not missing, \
        f"backends registered but absent from README table: {sorted(missing)}"
    for name, spec in BACKEND_REGISTRY.items():
        cells = rows[name]
        # column order: solutions, pallas, compaction, sparse, safe bound
        assert len(cells) == 5, \
            f"README row for {name} has {len(cells)} cells, expected 5"
        solutions, pallas, compaction, sparse, safe = cells
        assert solutions == ("exact" if spec.exact else "tolerance"), \
            f"README says {name} is {solutions!r}; registry exact={spec.exact}"
        for label, cell, flag in (
                ("Pallas", pallas, spec.supports_pallas),
                ("compaction", compaction, spec.supports_compaction),
                ("sparse", sparse, spec.supports_sparse),
                ("safe bound", safe, spec.supports_safe_bound)):
            assert cell == ("yes" if flag else "no"), \
                f"README {label} cell for {name} is {cell!r}; " \
                f"registry says {flag}"


def test_architecture_entry_points_exist():
    import repro.core as core
    names = sorted(set(re.findall(r"\bsolve_\w+", ARCHITECTURE.read_text())))
    assert names, "docs/architecture.md names no solve_* entry points"
    ghosts = [n for n in names if not hasattr(core, n)]
    assert not ghosts, \
        f"docs/architecture.md names entry points missing from " \
        f"repro.core: {ghosts}"


def test_architecture_registry_solvers_are_documented():
    # the per-backend table in architecture.md must name the registry's
    # actual solve targets (the attr half of each "module:attr" spec)
    text = ARCHITECTURE.read_text()
    for name, spec in BACKEND_REGISTRY.items():
        for field in ("solve", "solve_compacted", "solve_sparse"):
            target = getattr(spec, field)
            if not target:
                continue
            attr = target.split(":")[1]
            assert attr in text, \
                f"registry {name}.{field} -> {attr} not named in " \
                f"docs/architecture.md"


def test_architecture_mentions_interpret_only_kernel_status():
    text = ARCHITECTURE.read_text()
    assert "interpret=True" in text, \
        "docs/architecture.md must state the honest Pallas kernel status " \
        "(interpret=True-only validation)"


def test_architecture_observability_documents_every_lane():
    # the Observability section's lane table must name every counter the
    # telemetry plane actually collects — a new lane fails until documented
    from repro.obs.telemetry import ALL_LANES
    text = ARCHITECTURE.read_text()
    assert "## Observability" in text, \
        "docs/architecture.md lost its Observability section"
    obs = text.split("## Observability", 1)[1]
    ghosts = [lane for lane in ALL_LANES if f"`{lane}`" not in obs]
    assert not ghosts, \
        f"telemetry lanes missing from the docs/architecture.md " \
        f"Observability section: {ghosts}"


def test_readme_telemetry_quickstart_is_real():
    # README's telemetry snippet must reflect the actual API surface
    text = README.read_text()
    for needle in ("telemetry=True", "res.stats", "SolveReport",
                   "examples/serve_batched.py"):
        assert needle in text, \
            f"README.md telemetry quickstart lost: {needle!r}"
    from repro.obs import SolveReport
    for method in ("render", "summary"):
        assert hasattr(SolveReport, method), \
            f"README documents SolveReport.{method}() but it is gone"


@pytest.mark.skipif(not BENCH_JSON.exists(),
                    reason="no committed benchmark baseline")
def test_bench_readme_sections_match_json():
    d = json.loads(BENCH_JSON.read_text())
    json_sections = {k for k, v in d.items() if isinstance(v, list)}
    doc_sections = set(re.findall(r"^### `(\w+)`", BENCH_README.read_text(),
                                  flags=re.M))
    assert json_sections == doc_sections, \
        f"benchmarks/README.md sections {sorted(doc_sections)} != " \
        f"BENCH_pivot_work.json sections {sorted(json_sections)}"
