"""Per-arch smoke tests (reduced configs) + cache-correctness invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(3)
B, S = 2, 32


def _batch(r):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, r.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, r.vocab, (B, S)), jnp.int32),
    }
    if r.family == "vlm":
        batch["patches"] = jnp.asarray(
            RNG.normal(size=(B, r.n_patches, r.d_model)), jnp.float32)
    if r.family == "encdec":
        batch["frames"] = jnp.asarray(RNG.normal(size=(B, S, r.d_model)),
                                      jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    r = get_config(arch).reduced()
    model = build_model(r)
    params, specs = model.init(KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))
    loss, grads = jax.value_and_grad(model.loss_fn)(params, _batch(r))
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced()
    # disable capacity drops so MoE routing is batch-independent
    if cfg.mlp_kind == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    model = build_model(cfg)
    params, _ = model.init(KEY)
    batch = _batch(cfg)
    toks = batch["tokens"]
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patches"] = batch["patches"]
    if cfg.family == "encdec":
        kwargs["frames"] = batch["frames"]
    S0 = S - 4
    logits, caches = model.prefill(params, toks[:, :S0], **kwargs)
    old_len = (cfg.n_patches + S0) if cfg.family == "vlm" else S0

    def pad_seq(x):
        if x.ndim >= 3 and x.shape[2] == old_len:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 4)
            return jnp.pad(x, pad)
        return x

    caches = jax.tree.map(pad_seq, caches)
    for t in range(4):
        pos = jnp.full((B,), old_len + t, jnp.int32)
        logits, caches = model.decode_step(params, caches,
                                           toks[:, S0 + t], pos)
    ref, _ = model.prefill(params, toks, **kwargs)
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(logits - ref))) < 2e-4 * max(1.0, scale)


@pytest.mark.parametrize("arch", ["qwen3-32b", "hymba-1.5b"])
def test_loss_is_permutation_sensitive(arch):
    """Different tokens -> different loss (model isn't degenerate)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(KEY)
    b1 = _batch(cfg)
    b2 = dict(b1)
    b2["tokens"] = (b1["tokens"] + 7) % cfg.vocab
    l1 = float(model.loss_fn(params, b1))
    l2 = float(model.loss_fn(params, b2))
    assert l1 != l2


def test_param_counts_match_published():
    expected = {
        "deepseek-v2-236b": 236e9, "llama4-scout-17b-a16e": 109e9,
        "falcon-mamba-7b": 7.3e9, "whisper-small": 0.244e9,
        "qwen3-32b": 32.8e9, "granite-20b": 20.1e9,
        "nemotron-4-340b": 340e9, "llama3-405b": 405e9,
        "hymba-1.5b": 1.5e9, "phi-3-vision-4.2b": 4.2e9,
    }
    for arch, exp in expected.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda k: model.init(k)[0],
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        n = sum(int(x.size) for x in jax.tree.leaves(shapes))
        assert 0.85 <= n / exp <= 1.2, f"{arch}: {n/1e9:.1f}B vs {exp/1e9}B"


def test_sliding_window_limits_attention():
    """Hymba with window w: token far past the window doesn't affect logits."""
    cfg = dataclasses.replace(get_config("hymba-1.5b").reduced(),
                              sliding_window=8)
    model = build_model(cfg)
    params, _ = model.init(KEY)
    t1 = jnp.asarray(RNG.integers(0, cfg.vocab, (1, S)), jnp.int32)
    t2 = t1.at[0, 0].set((t1[0, 0] + 3) % cfg.vocab)
    l1, _ = model.prefill(params, t1)
    l2, _ = model.prefill(params, t2)
    # attention part is window-limited but the SSM still carries state, so
    # only check that attention cache shape honors the window
    assert model.cache_shape(1, S).kv.k.shape[2] == min(S, 8)
    del l1, l2


def test_moe_lp_capacity_router_runs():
    cfg = dataclasses.replace(get_config("deepseek-v2-236b").reduced(),
                              lp_capacity=True)
    model = build_model(cfg)
    params, _ = model.init(KEY)
    loss = model.loss_fn(params, _batch(cfg))
    assert np.isfinite(float(loss))
