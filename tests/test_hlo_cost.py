"""Trip-count-aware HLO cost model on a known program."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import module_cost


def test_scan_flops_multiplied_by_trip_count():
    M = 64
    L = 17

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.ones((M, M), jnp.float32)
    ws = jnp.ones((L, M, M), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    cost = module_cost(txt)
    expected = L * 2 * M ** 3
    assert 0.9 * expected <= cost["flops"] <= 1.2 * expected, \
        (cost["flops"], expected)


def test_flops_single_dot():
    a = jnp.ones((32, 48), jnp.float32)
    b = jnp.ones((48, 16), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text()
    cost = module_cost(txt)
    assert abs(cost["flops"] - 2 * 32 * 48 * 16) / (2 * 32 * 48 * 16) < 0.01


def test_collectives_counted_in_scan_body():
    import os, subprocess, sys, textwrap
    ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo_cost import module_cost
        from repro.distributed.sharding import make_mesh
        mesh = make_mesh((4,), ("model",))
        L, M = 9, 32
        def f(x, ws):
            def body(c, w):
                y = c @ w  # w sharded on cols -> partial matmul + AR-ish
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P(None, None)))
                return y @ w.T, None
            out, _ = jax.lax.scan(body, x, ws)
            return out
        x = jnp.ones((M, M))
        ws = jnp.ones((L, M, M))
        sh = NamedSharding(mesh, P(None, None, "model"))
        with mesh:
            txt = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)), sh)
                          ).lower(x, ws).compile().as_text()
        cost = module_cost(txt)
        total = cost["collectives"]["_total"]
        assert total["count"] >= L, total   # one collective per layer minimum
        print("COLL-OK", total)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr[-2500:]
    assert "COLL-OK" in r.stdout
