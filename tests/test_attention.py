"""Blockwise attention vs naive reference: exactness across chunk/window
configurations (the memory-optimized path must be bit-compatible with the
mathematical definition)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (blockwise_attention, decode_attention,
                                    repeat_kv)

RNG = np.random.default_rng(5)


def naive_attention(q, k, v, causal=True, window=None):
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("qc,kc", [(8, 8), (16, 4), (64, 64), (7, 13)])
@pytest.mark.parametrize("causal", [True, False])
def test_blockwise_matches_naive(qc, kc, causal):
    B, S, H, dh = 2, 64, 3, 8
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    ref = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("window", [4, 16, 63])
def test_sliding_window_matches_naive(window):
    B, S, H, dh = 1, 64, 2, 8
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=8,
                              window=window)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_decode_matches_naive_last_row():
    B, S, H, dh = 2, 32, 2, 8
    k = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    q = jnp.asarray(RNG.normal(size=(B, 1, H, dh)), jnp.float32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    out = decode_attention(q, k, v, pos)
    qfull = jnp.concatenate([jnp.zeros((B, S - 1, H, dh), jnp.float32), q], 1)
    ref = naive_attention(qfull, k, v, causal=True)[:, -1:]
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_repeat_kv_grouping():
    B, S, KV, dh, G = 1, 4, 2, 3, 3
    k = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), jnp.float32)
    rep = repeat_kv(k, G)
    assert rep.shape == (B, S, KV * G, dh)
    # kv-major ordering: head h uses kv h // G
    for h in range(KV * G):
        np.testing.assert_array_equal(rep[:, :, h], k[:, :, h // G])
