"""Sharder rules: divisibility guards, ZeRO-1 state specs, head padding."""
import subprocess, sys, os, textwrap

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=ROOT,
                       timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]
    return r.stdout


def test_rules_and_guards():
    out = _run("""
        import dataclasses, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed.sharding import Sharder, make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))

        # qwen3 reduced: 4 heads / tp=4 -> shardable
        cfg = dataclasses.replace(get_config("qwen3-32b").reduced(),
                                  n_heads=4, n_kv_heads=2, d_ff=128)
        shd = Sharder(cfg, mesh)
        assert shd.rules["heads"] == "model"
        assert shd.rules["ff"] == "model"
        assert shd.rules["kv_heads"] is None         # 2 % 4 != 0
        assert shd.rules["kv_seq"] == "model"        # cache falls back to seq

        # padding lifts divisibility
        cfg2 = dataclasses.replace(cfg, n_heads=5, n_heads_padded=8)
        assert Sharder(cfg2, mesh).rules["heads"] == "model"

        # act() guard: indivisible dims degrade to replicated
        x = jnp.ones((3, 8, 16))  # batch 3 not divisible by dp=2
        y = shd.act(x, "batch", None, "ff")
        assert "model" in str(y.sharding.spec), y.sharding

        # ZeRO-1: residual dim of moments gains 'data'
        spec = shd.opt_state_spec(("residual", "ff"))
        assert spec[0] == "data" and spec[1] == "model"
        print("RULES-OK")
    """)
    assert "RULES-OK" in out
