"""Unit tests for the HLO cost model's slice-aware traffic accounting."""
from repro.analysis.hlo_cost import HloModule, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[10]{0}") == 20
    assert _shape_bytes("(f32[2,2], s8[4])") == 20
    assert _shape_bytes("pred[]") == 1


HLO = """\
HloModule test

%fused_dus (param_0.1: f32[32,128], param_1.2: f32[1,128], param_2.3: s32[]) -> f32[32,128] {
  %param_0.1 = f32[32,128]{1,0} parameter(0)
  %param_1.2 = f32[1,128]{1,0} parameter(1)
  %param_2.3 = s32[] parameter(2)
  ROOT %dynamic-update-slice.1 = f32[32,128]{1,0} dynamic-update-slice(%param_0.1, %param_1.2, %param_2.3, %param_2.3)
}

%fused_ds (param_0.2: f32[32,128], param_1.3: s32[]) -> f32[1,128] {
  %param_0.2 = f32[32,128]{1,0} parameter(0)
  %param_1.3 = s32[] parameter(1)
  ROOT %dynamic-slice.2 = f32[1,128]{1,0} dynamic-slice(%param_0.2, %param_1.3, %param_1.3), dynamic_slice_sizes={1,128}
}

ENTRY %main (a: f32[32,128], u: f32[1,128], i: s32[]) -> f32[32,128] {
  %a = f32[32,128]{1,0} parameter(0)
  %u = f32[1,128]{1,0} parameter(1)
  %i = s32[] parameter(2)
  %slice_f = f32[1,128]{1,0} fusion(%a, %i), kind=kLoop, calls=%fused_ds
  ROOT %dus_f = f32[32,128]{1,0} fusion(%a, %slice_f, %i), kind=kLoop, calls=%fused_dus
}
"""


def test_fusion_slice_accounting():
    mod = HloModule(HLO)
    total = mod.total()
    # ds fusion: 2 x 512B slice; dus fusion: 2 x 512B update (+ no
    # full-buffer charges: 32x128xf32 = 16 KiB must NOT appear)
    assert total["mem_bytes"] == (2 * 512 + 4) + (2 * 512 + 4), total["mem_bytes"]


def test_dot_flops_with_batch_dims():
    hlo = """\
HloModule d

ENTRY %main (x: f32[4,8,16], y: f32[4,16,32]) -> f32[4,8,32] {
  %x = f32[4,8,16]{2,1,0} parameter(0)
  %y = f32[4,16,32]{2,1,0} parameter(1)
  ROOT %dot.1 = f32[4,8,32]{2,1,0} dot(%x, %y), lhs_batch_dims={0}, rhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_contracting_dims={1}
}
"""
    mod = HloModule(hlo)
    assert mod.total()["flops"] == 2 * 4 * 8 * 32 * 16
