"""Property-based tests (hypothesis) on LP-solver invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (LPBatch, OPTIMAL, random_lp_batch,
                        solve_batched_jax, solve_batched_reference,
                        solve_dual_reference)


@st.composite
def lp_dims(draw):
    m = draw(st.integers(min_value=2, max_value=20))
    n = draw(st.integers(min_value=2, max_value=15))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    feas = draw(st.booleans())
    return m, n, seed, feas


@settings(max_examples=25, deadline=None)
@given(lp_dims())
def test_primal_feasible_and_dominates_random_points(dims):
    m, n, seed, feas = dims
    rng = np.random.default_rng(seed)
    batch = random_lp_batch(rng, B=4, m=m, n=n, feasible_start=feas)
    res = solve_batched_jax(batch)
    ok = res.status == OPTIMAL
    if not ok.any():
        return
    A, b, c = batch.A[ok], batch.b[ok], batch.c[ok]
    x = res.x[ok]
    # feasibility, normalized by row activity (f32 tableau, no
    # preconditioning — faithful to the paper's Sec. 4 setup)
    act = np.einsum("bmn,bn->bm", np.abs(A), np.abs(x)) + np.abs(b) + 1.0
    viol = (np.einsum("bmn,bn->bm", A, x) - b) / act
    # f32 without pre-scaling (paper-faithful): worst-case adversarial draws
    # reach ~1e-3 normalized violation; the f64 oracle in test_simplex pins
    # the tight bound
    assert viol.max() <= 5e-3
    assert x.min() >= -1e-5
    # optimality: no random feasible point beats the solver
    y = np.abs(rng.normal(size=(8, x.shape[0], n))) * 0.05
    feas_mask = (np.einsum("bmn,kbn->kbm", A, y) <= b[None] + 1e-9).all(-1)
    obj_y = np.einsum("bn,kbn->kb", c, y)
    obj_star = res.objective[ok]
    assert np.all(obj_y[feas_mask] <= (obj_star[None].repeat(8, 0)[feas_mask]
                                       * (1 + 1e-4) + 1e-4))


@settings(max_examples=15, deadline=None)
@given(lp_dims())
def test_strong_duality(dims):
    m, n, seed, feas = dims
    rng = np.random.default_rng(seed)
    batch = random_lp_batch(rng, B=3, m=m, n=n, feasible_start=feas)
    primal = solve_batched_reference(batch)
    dual = solve_dual_reference(batch)
    ok = (primal.status == OPTIMAL) & (dual.status == OPTIMAL)
    if not ok.any():
        return
    gap = np.abs(primal.objective[ok] - dual.objective[ok])
    assert gap.max() <= 1e-6 * (1 + np.abs(primal.objective[ok]).max())


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.floats(min_value=0.1, max_value=10.0))
def test_objective_scaling_invariant(seed, alpha):
    """Scaling c by alpha scales the optimum by alpha (same argmax)."""
    rng = np.random.default_rng(seed)
    batch = random_lp_batch(rng, B=4, m=8, n=6)
    r1 = solve_batched_jax(batch)
    batch2 = LPBatch(A=batch.A, b=batch.b, c=batch.c * alpha)
    r2 = solve_batched_jax(batch2)
    ok = (r1.status == OPTIMAL) & (r2.status == OPTIMAL)
    np.testing.assert_allclose(r2.objective[ok], alpha * r1.objective[ok],
                               rtol=1e-3)
