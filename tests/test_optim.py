"""Optimizers: convergence on a quadratic + state spec shapes."""
import jax
import jax.numpy as jnp

from repro.optim import adafactor, adamw


def _converges(opt, steps=200):
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((2, 3))}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["m"] - 0.5) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
    return l0, float(loss_fn(params))


def test_adamw_converges():
    l0, l1 = _converges(adamw(lr=0.05, weight_decay=0.0))
    assert l1 < 0.05 * l0


def test_adafactor_converges():
    l0, l1 = _converges(adafactor(lr=0.1))
    assert l1 < 0.1 * l0


def test_state_logical_specs():
    opt = adafactor()
    specs = {"w": ("residual", "ff")}
    slog = opt.state_logical(specs)
    assert slog["v"]["w"] == {"vr": ("residual",), "vc": ("ff",)}
    opt2 = adamw()
    assert opt2.state_logical(specs)["m"]["w"] == ("residual", "ff")
