"""Phase-compacted tableau correctness: the two-loop solvers (pure JAX and
Pallas interpret) against each other, the seed single-loop solver, and the
float64 oracle — including LPs that skip phase 1 entirely."""
import numpy as np
import pytest

from repro.core import (OPTIMAL, LPBatch, random_lp_batch, solve_batched_jax,
                        solve_batched_reference)
from repro.kernels import compacted_dims, full_dims, solve_batched_pallas

RNG = np.random.default_rng(13)


def test_compacted_dims_shrink():
    R, C = full_dims(100, 100)
    R2, C2 = compacted_dims(100, 100)
    assert (R2, C2) == (104, 256) and (R, C) == (104, 384)
    # logical shrink exists even when lane padding hides it at small sizes
    assert compacted_dims(28, 28)[1] <= full_dims(28, 28)[1]


@pytest.mark.parametrize("feas", [True, False])
def test_phase_compaction_identical_to_single_loop(feas):
    """Dropping artificial columns + the phase-1 row changes no pivot
    decision: two-loop and seed single-loop solves are bit-identical."""
    batch = random_lp_batch(RNG, B=24, m=12, n=9, feasible_start=feas)
    two_loop = solve_batched_jax(batch)
    single = solve_batched_jax(batch, phase_compaction=False)
    np.testing.assert_array_equal(two_loop.status, single.status)
    np.testing.assert_array_equal(two_loop.iterations, single.iterations)
    np.testing.assert_array_equal(two_loop.x, single.x)
    np.testing.assert_array_equal(np.nan_to_num(two_loop.objective),
                                  np.nan_to_num(single.objective))


def test_pallas_compacted_path_skips_phase1():
    """All-feasible batch: loop 1 executes zero pivots, the whole solve runs
    on the compacted tableau — Pallas (interpret) vs pure JAX bitwise."""
    batch = random_lp_batch(RNG, B=17, m=10, n=7, feasible_start=True)
    jx = solve_batched_jax(batch)
    pal = solve_batched_pallas(batch, tile_b=8)
    np.testing.assert_array_equal(jx.status, pal.status)
    np.testing.assert_array_equal(jx.iterations, pal.iterations)
    ok = jx.status == OPTIMAL
    assert ok.all()
    np.testing.assert_allclose(jx.objective[ok], pal.objective[ok], rtol=1e-5)


@pytest.mark.parametrize("m,n", [(5, 5), (12, 8), (28, 28)])
def test_pallas_compacted_path_mixed(m, n):
    """Mixed batch: some LPs pivot through both loops, some only loop 2."""
    rng = np.random.default_rng(m * 100 + n)
    f = random_lp_batch(rng, 9, m, n, feasible_start=True)
    i = random_lp_batch(rng, 9, m, n, feasible_start=False)
    batch = LPBatch(A=np.concatenate([f.A, i.A]),
                    b=np.concatenate([f.b, i.b]),
                    c=np.concatenate([f.c, i.c]))
    jx = solve_batched_jax(batch)
    pal = solve_batched_pallas(batch, tile_b=8)
    np.testing.assert_array_equal(jx.status, pal.status)
    np.testing.assert_array_equal(jx.iterations, pal.iterations)
    ref = solve_batched_reference(batch)
    assert (ref.status == pal.status).mean() >= 0.95


def test_pallas_scheduler_composes():
    """solve_batched_pallas(compaction=True): segment kernels + bucket
    ladder return the same results as the whole-solve kernel."""
    rng = np.random.default_rng(71)
    f = random_lp_batch(rng, 20, 10, 8, feasible_start=True)
    i = random_lp_batch(rng, 12, 10, 8, feasible_start=False)
    batch = LPBatch(A=np.concatenate([f.A, i.A]),
                    b=np.concatenate([f.b, i.b]),
                    c=np.concatenate([f.c, i.c]))
    whole = solve_batched_pallas(batch, tile_b=8)
    stats = []
    sched = solve_batched_pallas(batch, tile_b=8, compaction=True,
                                 segment_k=4, stats_out=stats)
    np.testing.assert_array_equal(whole.status, sched.status)
    np.testing.assert_array_equal(whole.iterations, sched.iterations)
    np.testing.assert_array_equal(np.nan_to_num(whole.objective),
                                  np.nan_to_num(sched.objective))
    # buckets are tile_b multiples and the ladder engaged
    assert all(s.bucket % 8 == 0 for s in stats)
    assert len(stats) >= 2
