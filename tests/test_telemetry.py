"""Solver telemetry plane (repro.obs): counter parity against the float64
oracle, survival through the compaction scheduler and the chunked-sorted
driver, the telemetry=False zero-overhead guarantee, and the span-tracer
exporters.

The iteration-attribution invariant under test everywhere:
``phase1_iters + phase2_iters == LPResult.iterations`` exactly, on every
engine and every scheduling path.  On well-conditioned workloads the f32
engines execute the oracle's pivot sequence, so the per-phase lanes must
also be bit-equal to the f64 reference's counts.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import (OPTIMAL, LPBatch, random_lp_batch, solve_batched,
                        solve_batched_compacted, solve_batched_jax,
                        solve_batched_pdhg, solve_batched_pdhg_compacted,
                        solve_batched_reference_detailed,
                        solve_batched_revised,
                        solve_batched_revised_compacted)
from repro.io.mps import fixture_path, perturbed_batch, read_mps
from repro.obs import SolveReport, SpanTracer
from repro.obs.telemetry import ALL_LANES, F32_LANES, INT_LANES
from repro.obs.work import element_updates_lockstep, lockstep_steps

@pytest.fixture(scope="module", autouse=True)
def _release_telemetry_executables():
    """Drop this module's compiled executables when it finishes.

    Every telemetry=True solve retraces an engine with the counter lanes
    in the carry, so this module roughly doubles the number of large
    XLA CPU executables held by the process.  Keeping them alive pushes
    the suite's accumulated JIT code far enough that a *later* module's
    compile segfaults inside XLA (deterministically, at whatever compile
    happens to come next — test_warm.py in alphabetical order).  Clearing
    the caches releases the executables; later modules just recompile
    their own traces.
    """
    yield
    jax.clear_caches()


ENGINES = {
    "tableau": solve_batched_jax,
    "revised": solve_batched_revised,
    "pdhg": solve_batched_pdhg,
}
EXACT = ("tableau", "revised")  # pivot engines: oracle-exact paths
# fixtures where the f32 engines execute the f64 oracle's exact pivot
# sequence (the staircase fixtures diverge in float, not in telemetry)
PARITY_FIXTURES = ("afiro", "testprob")


def _mixed_batch(rng, B=24, m=6, n=6):
    """Half feasible-start, half phase-1 LPs — exercises both lanes."""
    half = B // 2
    b1 = random_lp_batch(rng, half, m, n, feasible_start=True)
    b2 = random_lp_batch(rng, B - half, m, n, feasible_start=False)
    batch = LPBatch(A=np.concatenate([b1.A, b2.A]),
                    b=np.concatenate([b1.b, b2.b]),
                    c=np.concatenate([b1.c, b2.c]))
    perm = rng.permutation(B)
    return LPBatch(A=batch.A[perm], b=batch.b[perm], c=batch.c[perm])


def _degenerate_batch(rng, B=8, m=6, n=6):
    """Feasible-start LPs with zeroed rhs rows: the first pivots hit
    min_ratio == 0, so the degenerate_pivots lane must fire."""
    batch = random_lp_batch(rng, B, m, n, feasible_start=True)
    b = batch.b.copy()
    b[:, :2] = 0.0
    return LPBatch(A=batch.A, b=b, c=batch.c)


def _assert_report_consistent(res, backend):
    rep = res.stats
    assert isinstance(rep, SolveReport)
    assert set(rep.counters) == set(ALL_LANES)
    np.testing.assert_array_equal(rep.iterations,
                                  np.asarray(res.iterations))
    for name in INT_LANES:
        assert rep.lane(name).dtype == np.int32
        assert (rep.lane(name) >= 0).all(), name
    return rep


# ---------------------------------------------------------------------------
# counter parity vs the float64 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", EXACT)
@pytest.mark.parametrize("fixture", PARITY_FIXTURES)
def test_fixture_parity_vs_oracle(backend, fixture):
    g = read_mps(fixture_path(fixture))
    batch = perturbed_batch(g, 6, np.random.default_rng(0))
    ref, p1 = solve_batched_reference_detailed(batch)
    res = solve_batched(batch, backend=backend, telemetry=True)
    rep = _assert_report_consistent(res, backend)
    np.testing.assert_array_equal(res.status, ref.status)
    np.testing.assert_array_equal(rep.iterations, ref.iterations)
    np.testing.assert_array_equal(rep.lane("phase1_iters"), p1)


@pytest.mark.parametrize("backend", EXACT)
def test_dense_feasible_parity(backend):
    """Feasible-start dense batch: the engines skip phase 1 entirely (the
    oracle charges its feasibility check as one phase-1 iteration), so the
    phase-2 lane alone must be bit-equal to the oracle's phase-2 count."""
    batch = random_lp_batch(np.random.default_rng(3), 16, 6, 6,
                            feasible_start=True)
    ref, p1 = solve_batched_reference_detailed(batch)
    res = solve_batched(batch, backend=backend, telemetry=True)
    rep = _assert_report_consistent(res, backend)
    np.testing.assert_array_equal(res.status, ref.status)
    assert not rep.lane("phase1_iters").any()
    np.testing.assert_array_equal(rep.lane("phase2_iters"),
                                  np.asarray(ref.iterations) - p1)


def test_phase1_dense_parity_revised():
    """Phase-1-needing dense batch: the revised engine follows the oracle's
    pivot path exactly, so both per-phase lanes are bit-equal."""
    batch = random_lp_batch(np.random.default_rng(1), 16, 6, 6,
                            feasible_start=False)
    ref, p1 = solve_batched_reference_detailed(batch)
    res = solve_batched_revised(batch, telemetry=True)
    rep = _assert_report_consistent(res, "revised")
    np.testing.assert_array_equal(rep.iterations, ref.iterations)
    np.testing.assert_array_equal(rep.lane("phase1_iters"), p1)
    assert rep.lane("phase1_iters").any()
    assert rep.lane("phase2_iters").any()


@pytest.mark.parametrize("backend", EXACT)
def test_degenerate_pivots_lane(backend):
    batch = _degenerate_batch(np.random.default_rng(11))
    res = solve_batched(batch, backend=backend, telemetry=True)
    rep = _assert_report_consistent(res, backend)
    assert rep.lane("degenerate_pivots").any(), \
        "zeroed rhs rows must produce min_ratio == 0 pivots"
    # pivots can never exceed iterations (blocked/flip steps don't pivot)
    assert (rep.pivots <= rep.iterations).all()


def test_pdhg_lanes():
    batch = _mixed_batch(np.random.default_rng(5), B=12)
    res = solve_batched_pdhg(batch, telemetry=True)
    rep = _assert_report_consistent(res, "pdhg")
    # PDHG is single-phase: every iteration lands in the phase-2 lane
    assert not rep.lane("phase1_iters").any()
    ok = np.asarray(res.status) == OPTIMAL
    assert ok.any()
    for name in ("kkt_primal", "kkt_dual", "kkt_gap"):
        vals = rep.lane(name)[ok]
        assert np.isfinite(vals).all() and (vals >= 0).all(), name
    assert (rep.lane("omega")[ok] > 0).all()


def test_revised_refactor_lanes():
    batch = _mixed_batch(np.random.default_rng(7), B=16)
    res = solve_batched_revised(batch, refactor_period=4, telemetry=True)
    rep = _assert_report_consistent(res, "revised")
    assert rep.lane("refactorizations").any(), \
        "a period-4 refactor schedule must fire on multi-pivot solves"
    # the eta file is bounded by the refactor period
    assert (rep.lane("eta_len") <= 4).all()


# ---------------------------------------------------------------------------
# counters survive the compaction scheduler and the chunked driver
# ---------------------------------------------------------------------------

def test_counters_survive_bucket_shrink():
    batch = _mixed_batch(np.random.default_rng(9), B=32)
    mono = solve_batched_jax(batch, telemetry=True)
    stats = []
    sched = solve_batched_compacted(batch, segment_k=4, telemetry=True,
                                    stats_out=stats)
    buckets = [s.bucket for s in stats]
    assert min(buckets) < max(buckets), "batch too easy: no bucket shrink"
    rep = _assert_report_consistent(sched, "tableau")
    # scheduled == monolithic on every lane: gathers never touch counters
    for name in ALL_LANES:
        np.testing.assert_array_equal(rep.lane(name),
                                      mono.stats.lane(name), err_msg=name)


@pytest.mark.parametrize("solver", [solve_batched_revised_compacted,
                                    solve_batched_pdhg_compacted])
def test_counters_survive_compaction_other_engines(solver):
    batch = _mixed_batch(np.random.default_rng(13), B=16)
    res = solver(batch, segment_k=4, telemetry=True)
    _assert_report_consistent(res, solver.__name__)
    assert res.stats.iterations.any()


def test_counters_survive_chunked_sorted_roundtrip():
    batch = _mixed_batch(np.random.default_rng(15), B=24)
    mono = solve_batched_jax(batch, telemetry=True)
    chunked = solve_batched(batch, chunk_size=7, sort_by_difficulty=True,
                            telemetry=True)
    rep = _assert_report_consistent(chunked, "tableau")
    np.testing.assert_array_equal(chunked.status, mono.status)
    # the permute/chunk/unpermute round-trip must return every LP's own
    # counters to its original slot
    for name in ALL_LANES:
        np.testing.assert_array_equal(rep.lane(name),
                                      mono.stats.lane(name), err_msg=name)


# ---------------------------------------------------------------------------
# telemetry=False: the zero-overhead guarantee
# ---------------------------------------------------------------------------

def _core_jaxpr(backend, batch, **kw):
    from repro.core.pdhg import _solve_pdhg_core
    from repro.core.revised import _solve_revised_core
    from repro.core.simplex import _solve_core
    import jax.numpy as jnp

    A = jnp.asarray(batch.A, jnp.float32)
    b = jnp.asarray(batch.b, jnp.float32)
    c = jnp.asarray(batch.c, jnp.float32)
    ub = jnp.full((batch.batch, batch.n), jnp.inf, jnp.float32)
    m, n = batch.m, batch.n
    if backend == "tableau":
        fn = lambda: _solve_core(A, b, c, ub, m=m, n=n, max_iters=50,
                                 tol=1e-6, feas_tol=1e-5, **kw)
    elif backend == "revised":
        fn = lambda: _solve_revised_core(A, b, c, ub, m=m, n=n, max_iters=50,
                                         tol=1e-6, feas_tol=1e-5,
                                         refactor_period=4,
                                         pricing="dantzig", **kw)
    else:
        fn = lambda: _solve_pdhg_core(A, b, c, ub, m=m, n=n, max_iters=200,
                                      tol=1e-4, check_every=8, **kw)
    return str(jax.make_jaxpr(fn)())


@pytest.mark.parametrize("backend", ["tableau", "revised", "pdhg"])
def test_telemetry_off_is_default_and_trace_identical(backend):
    batch = random_lp_batch(np.random.default_rng(0), 4, 4, 4)
    default = _core_jaxpr(backend, batch)
    off = _core_jaxpr(backend, batch, telemetry=False)
    on = _core_jaxpr(backend, batch, telemetry=True)
    # the default path IS the telemetry-off path, byte-identical: the tel
    # slot is an empty pytree (None), adding no inputs, carries or outputs
    assert default == off
    # telemetry=True retraces with extra carry lanes and outputs
    assert on != off
    assert len(on) > len(off)


def test_off_state_has_no_extra_leaves():
    """The engine states carry ``tel=None`` when telemetry is off — JAX
    flattens None to zero leaves, so the off-path pytrees are structurally
    identical to the pre-telemetry states (that is the whole trick)."""
    from repro.core.simplex import solve_two_phase  # noqa: F401
    from repro.obs.telemetry import init_telemetry

    tel = init_telemetry(4)
    n_lanes = len(jax.tree_util.tree_leaves(tel))
    assert n_lanes == len(ALL_LANES) == len(INT_LANES) + len(F32_LANES)
    assert len(jax.tree_util.tree_leaves(None)) == 0


@pytest.mark.parametrize("backend", ["tableau", "revised", "pdhg"])
def test_stats_none_when_disabled(backend):
    batch = random_lp_batch(np.random.default_rng(2), 4, 4, 4)
    res = ENGINES[backend](batch)
    assert res.stats is None
    on = ENGINES[backend](batch, telemetry=True)
    # turning telemetry on never changes the answers
    np.testing.assert_array_equal(res.status, on.status)
    np.testing.assert_array_equal(res.iterations, on.iterations)


# ---------------------------------------------------------------------------
# span tracer + exporters
# ---------------------------------------------------------------------------

def test_perfetto_export_valid_and_nested(tmp_path):
    batch = _mixed_batch(np.random.default_rng(21), B=32)
    tr = SpanTracer()
    with tr.span("solve", B=batch.batch):
        res = solve_batched_compacted(batch, segment_k=4, telemetry=True,
                                      tracer=tr)
    rep = res.stats
    assert rep.spans, "run_schedule must attach the tracer's span tree"
    path = tmp_path / "trace.json"
    rep.to_perfetto(str(path))
    doc = json.loads(path.read_text())  # valid JSON
    events = doc["traceEvents"]
    spans = [e for e in events if e["ph"] == "X"]
    names = {e["name"] for e in spans}
    assert any(nm.startswith("segment[") for nm in names), names
    assert "canonicalize" in names and "dispatch" in names
    # proper nesting: every segment span lies inside the root solve span
    root = next(e for e in spans if e["name"] == "solve")
    for e in spans:
        if e["name"].startswith("segment["):
            assert e["ts"] >= root["ts"] - 1e-6
            assert e["ts"] + e["dur"] <= root["ts"] + root["dur"] + 1e-6
    # flush instants carried through as instant events
    assert any(e["ph"] == "i" for e in events)


def test_jsonl_stream_unifies_segments_and_events():
    batch = _mixed_batch(np.random.default_rng(23), B=16)
    tr = SpanTracer()
    solve_batched_compacted(batch, segment_k=4, telemetry=True, tracer=tr)
    lines = [json.loads(ln) for ln in tr.to_jsonl().splitlines()]
    kinds = {(rec["type"], rec["name"]) for rec in lines}
    assert ("event", "flush") in kinds
    assert any(t == "span" and nm.startswith("segment[") for t, nm in kinds)


def test_report_algebra_and_summary():
    batch = _mixed_batch(np.random.default_rng(25), B=12)
    res = solve_batched_jax(batch, telemetry=True)
    rep = res.stats
    assert rep.batch_size == 12
    sliced = rep.slice(2, 8)
    assert sliced.batch_size == 6
    np.testing.assert_array_equal(sliced.iterations, rep.iterations[2:8])
    idx = np.array([3, 1, 2])
    np.testing.assert_array_equal(rep.take(idx).iterations,
                                  rep.iterations[idx])
    back = SolveReport.concat([rep.slice(0, 5), rep.slice(5, 12)])
    np.testing.assert_array_equal(back.iterations, rep.iterations)
    s = rep.summary()
    assert s["batch_size"] == 12
    assert s["iterations_total"] == int(rep.iterations.sum())
    assert "phase2_iters" in s["lanes"]
    assert "SolveReport" in rep.render()


# ---------------------------------------------------------------------------
# the shared work-accounting helper (obs.work)
# ---------------------------------------------------------------------------

def test_work_helper_matches_bespoke_formula():
    from repro.core.simplex import tableau_elements

    iters = np.array([3, 7, 1, 4])
    assert lockstep_steps(iters) == 8
    assert element_updates_lockstep(iters, 5, 6) == \
        8 * 4 * tableau_elements(5, 6)
    # telemetry-sourced counts feed the same helper the bench uses
    batch = random_lp_batch(np.random.default_rng(27), 8, 5, 5)
    res = solve_batched_jax(batch, telemetry=True)
    assert element_updates_lockstep(res.stats.iterations, 5, 5) == \
        element_updates_lockstep(np.asarray(res.iterations), 5, 5)
