"""Property suite for the revised-simplex backend (core/revised.py).

The revised engine keeps (A, b, c) immutable and pivots on a product-form
basis inverse (eta file + periodic LU refactorization), so the invariants
split in two:

* **certificates** — statuses must match the tableau backend and the float64
  oracle on every batch class (dense, sparse, degenerate,
  infeasible/unbounded), and optimal objectives must agree to tolerance.
  Pivot *paths* may differ: revised recomputes f32 reduced costs instead of
  carrying them through rank-1 updates, so degenerate near-ties can order
  differently without changing any certificate.
* **engine invariance** — for a fixed engine configuration the pivot
  sequence is deterministic: refactorization period must not change
  certificates (period 1 = fresh LU every pivot is the exact reference),
  compaction-scheduler gathers must round-trip the eta/LU state, and
  partial pricing must agree with full pricing on final statuses.
"""
import warnings

import numpy as np
import pytest

from repro.core import (
    ITERATION_LIMIT,
    OPTIMAL,
    LPBatch,
    auto_compact_threshold,
    auto_refactor_period,
    random_lp_batch,
    random_sparse_lp_batch,
    revised_elements,
    solve_batched,
    solve_batched_compacted,
    solve_batched_jax,
    solve_batched_reference,
    solve_batched_revised,
    solve_batched_revised_compacted,
    solve_pjit,
    solve_shard_map,
    tableau_elements,
)
from repro.analysis.lp_perf import (
    revised_crossover,
    revised_pivot_flops,
    tableau_pivot_flops,
)
from repro.core.revised import REVISED_RULES, canonicalize_revised_rule
from repro.distributed.sharding import make_mesh
from repro.kernels import solve_batched_pallas


def _mixed_batch(rng, B_each=8, m=10, n=8):
    f = random_lp_batch(rng, B_each, m, n, feasible_start=True)
    p1 = random_lp_batch(rng, B_each, m, n, feasible_start=False)
    return LPBatch(A=np.concatenate([f.A, p1.A]),
                   b=np.concatenate([f.b, p1.b]),
                   c=np.concatenate([f.c, p1.c]))


def _assert_same_certificates(a, b, rtol=1e-4):
    np.testing.assert_array_equal(a.status, b.status)
    ok = a.status == OPTIMAL
    np.testing.assert_allclose(a.objective[ok], b.objective[ok], rtol=rtol)


# ---------------------------------------------------------------------------
# certificates vs tableau backend and float64 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pricing", REVISED_RULES)
def test_revised_matches_tableau_and_oracle_dense(pricing):
    batch = _mixed_batch(np.random.default_rng(11))
    rev = solve_batched_revised(batch, pricing=pricing)
    _assert_same_certificates(solve_batched_jax(batch), rev)
    _assert_same_certificates(solve_batched_reference(batch), rev)


@pytest.mark.parametrize("pricing", REVISED_RULES)
def test_revised_matches_oracle_sparse(pricing):
    batch = random_sparse_lp_batch(np.random.default_rng(7), B=12, m=14, n=10,
                                   density=0.15)
    rev = solve_batched_revised(batch, pricing=pricing)
    _assert_same_certificates(solve_batched_reference(batch), rev)


def test_revised_matches_oracle_degenerate():
    """Duplicated rows + zero slack at the optimum: degenerate pivots with
    theta = 0 must terminate with the same certificates."""
    rng = np.random.default_rng(23)
    base = random_lp_batch(rng, 12, 6, 6)
    A = np.concatenate([base.A, base.A[:, :3, :]], axis=1)  # duplicate rows
    b = np.concatenate([base.b, base.b[:, :3]], axis=1)
    batch = LPBatch.from_arrays(A, b, base.c)
    rev = solve_batched_revised(batch)
    _assert_same_certificates(solve_batched_reference(batch), rev)
    assert (rev.status == OPTIMAL).all()


def test_revised_infeasible_and_unbounded():
    # x0 <= 1 and -x0 <= -2 is infeasible; max x0 with only x1 bounded is
    # unbounded
    A_inf = np.zeros((3, 2, 2))
    A_inf[:, 0, 0] = 1.0
    A_inf[:, 1, 0] = -1.0
    b_inf = np.tile(np.array([1.0, -2.0]), (3, 1))
    inf = LPBatch.from_arrays(A_inf, b_inf, np.ones((3, 2)))
    A_unb = np.zeros((2, 1, 2))
    A_unb[:, 0, 1] = 1.0
    unb = LPBatch.from_arrays(A_unb, np.ones((2, 1)),
                              np.tile(np.array([1.0, 0.0]), (2, 1)))
    for batch in (inf, unb):
        tab = solve_batched_jax(batch)
        for pricing in REVISED_RULES:
            rev = solve_batched_revised(batch, pricing=pricing)
            np.testing.assert_array_equal(tab.status, rev.status)
            np.testing.assert_array_equal(
                solve_batched_reference(batch).status, rev.status)


def test_revised_solution_is_feasible():
    """The extracted x must satisfy Ax <= b, x >= 0 (not just the objective)."""
    batch = _mixed_batch(np.random.default_rng(31), m=8, n=12)
    rev = solve_batched_revised(batch)
    ok = rev.status == OPTIMAL
    assert ok.any()
    ax = np.einsum("bmn,bn->bm", batch.A[ok], rev.x[ok])
    assert (ax <= batch.b[ok] + 1e-3 * np.abs(batch.b[ok]) + 1e-3).all()
    assert (rev.x[ok] >= -1e-5).all()


# ---------------------------------------------------------------------------
# engine invariance
# ---------------------------------------------------------------------------

def test_refactorization_invariance():
    """Eta-file length is a cost knob, not a semantic: period 1 (fresh LU
    every pivot — the exact reference) and period 16 must produce the same
    certificates, and near-identical objectives."""
    batch = _mixed_batch(np.random.default_rng(5), m=12, n=12)
    r1 = solve_batched_revised(batch, refactor_period=1)
    r16 = solve_batched_revised(batch, refactor_period=16)
    _assert_same_certificates(r1, r16, rtol=1e-4)
    # and the auto-derived period agrees too
    rauto = solve_batched_revised(batch)
    _assert_same_certificates(r1, rauto, rtol=1e-4)
    assert auto_refactor_period(12, 12) == max(4, min(64, 6))


def test_compaction_gather_round_trip():
    """Bucket gathers carry the eta file / LU factors / basis across shrinks
    (with refactor-on-compact): the scheduled solve must reproduce the
    monolithic solve's certificates on every batch slot, and the bucket
    ladder must actually shrink."""
    rng = np.random.default_rng(17)
    batch = _mixed_batch(rng, B_each=24, m=10, n=10)
    mono = solve_batched_revised(batch)
    stats = []
    sched = solve_batched_revised_compacted(batch, segment_k=4,
                                            stats_out=stats)
    _assert_same_certificates(mono, sched)
    np.testing.assert_array_equal(mono.iterations, sched.iterations)
    buckets = {s.bucket for s in stats}
    assert len(buckets) > 1, f"no bucket shrink observed: {buckets}"
    assert all(s.elements == s.steps * s.bucket * revised_elements(10, 10)
               for s in stats)


def test_partial_pricing_agrees_with_full():
    """Partial pricing scans blocks (n+m > PARTIAL_BLOCK here, so the block
    schedule is real) and must reach the same final statuses as full
    pricing, monolithic and under the scheduler."""
    rng = np.random.default_rng(41)
    batch = random_lp_batch(rng, 24, 20, 110, feasible_start=False)
    full = solve_batched_revised(batch, pricing="dantzig")
    part = solve_batched_revised(batch, pricing="partial")
    _assert_same_certificates(full, part, rtol=1e-3)
    parts = solve_batched_revised_compacted(batch, segment_k=6,
                                            pricing="partial")
    _assert_same_certificates(full, parts, rtol=1e-3)
    # partial must actually have taken a different path somewhere (blocks
    # reorder entering choices on LPs with many candidate columns)
    assert not np.array_equal(full.iterations, part.iterations)


def test_revised_rejects_weighted_rules():
    batch = random_lp_batch(np.random.default_rng(0), 2, 4, 4)
    with pytest.raises(ValueError, match="tableau-only"):
        solve_batched_revised(batch, pricing="steepest_edge")
    with pytest.raises(ValueError, match="tableau-only"):
        canonicalize_revised_rule("devex")


# ---------------------------------------------------------------------------
# entry-point threading
# ---------------------------------------------------------------------------

def test_backend_on_solve_batched_and_chunking():
    rng = np.random.default_rng(3)
    batch = _mixed_batch(rng, B_each=16, m=8, n=8)
    base = solve_batched_revised(batch)
    via = solve_batched(batch, backend="revised")
    _assert_same_certificates(base, via)
    np.testing.assert_array_equal(base.iterations, via.iterations)
    chunked = solve_batched(batch, backend="revised", chunk_size=8,
                            sort_by_difficulty=True, compaction=True)
    _assert_same_certificates(base, chunked)


def test_backend_on_distributed_paths():
    rng = np.random.default_rng(13)
    batch = _mixed_batch(rng, B_each=8, m=6, n=6)
    mesh = make_mesh((1,), ("data",))
    base = solve_batched_revised(batch)
    pj = solve_pjit(batch, mesh, backend="revised")
    _assert_same_certificates(base, pj)
    np.testing.assert_array_equal(base.iterations, pj.iterations)
    sm = solve_shard_map(batch, mesh, backend="revised")
    _assert_same_certificates(base, sm)
    sms = solve_shard_map(batch, mesh, backend="revised", segment_k=4,
                          pricing="partial")
    np.testing.assert_array_equal(base.status, sms.status)


def test_backend_on_pallas_runs_tile_kernel():
    """backend="revised" on the Pallas entry point runs the real tile
    kernel (kernels/revised_tile.py): no fallback warning, statuses and
    pivot counts identical to the pure-JAX engine, objectives to f32
    tolerance (the dense basis inverse rounds differently than the
    engine's triangular solves)."""
    import warnings as _w
    from repro.kernels import ops

    rng = np.random.default_rng(29)
    batch = _mixed_batch(rng, B_each=8, m=6, n=6)
    base = solve_batched_revised(batch)
    ops._WARNED.discard("revised-fallback")
    ops._WARNED.discard("partial-pricing")
    with _w.catch_warnings():
        _w.simplefilter("error")       # any fallback warning is a failure
        pal = solve_batched_pallas(batch, backend="revised", tile_b=8)
    np.testing.assert_array_equal(base.status, pal.status)
    np.testing.assert_array_equal(base.iterations, pal.iterations)
    ok = base.status == OPTIMAL
    np.testing.assert_allclose(pal.objective[ok], base.objective[ok],
                               rtol=1e-4, atol=1e-4)
    # the tableau tile kernel still degrades partial->dantzig with its
    # one warning (full cost row is VMEM-resident there)
    with pytest.warns(UserWarning, match="partial pricing saves nothing"):
        ppal = solve_batched_pallas(batch, tile_b=8, pricing="partial")
    np.testing.assert_array_equal(solve_batched_jax(batch).status,
                                  ppal.status)


def test_unknown_backend_rejected_everywhere():
    batch = random_lp_batch(np.random.default_rng(0), 2, 4, 4)
    for fn in (lambda: solve_batched_jax(batch, backend="dense"),
               lambda: solve_batched(batch, backend="dense"),
               lambda: solve_batched_pallas(batch, backend="dense")):
        with pytest.raises(ValueError, match="unknown backend"):
            fn()


# ---------------------------------------------------------------------------
# work model + compaction auto-threshold satellite
# ---------------------------------------------------------------------------

def test_revised_element_model_beats_tableau_at_100():
    """The acceptance bar: at 100x100 (and up the Table-2 ladder) revised
    element updates per pivot undercut even the phase-compacted tableau's."""
    for (m, n) in [(100, 100), (150, 150), (100, 400)]:
        assert revised_elements(m, n) < tableau_elements(m, n, compacted=True)
        assert revised_elements(m, n, partial=True) < revised_elements(m, n)
    # flops model is honest: dense square stays tableau-territory, the
    # crossover appears as n grows past a few multiples of m
    assert revised_pivot_flops(100, 100) > tableau_pivot_flops(
        100, 100, compacted=True)
    xo = revised_crossover(100)
    assert xo is not None and 100 < xo < 1000
    assert revised_pivot_flops(100, xo, partial=True) < tableau_pivot_flops(
        100, xo, compacted=True)


def test_auto_compact_threshold():
    """Derived threshold: monotone in segment_k, never more eager than a
    gather can pay for at tiny segments, and a drop-in for the static 0.5
    (identical results, never more executed elements)."""
    assert auto_compact_threshold(1) < 0.5  # gather rivals a 1-pivot segment
    assert auto_compact_threshold(2) == pytest.approx(0.5)
    ts = [auto_compact_threshold(k) for k in (1, 2, 4, 8, 32, 200)]
    assert ts == sorted(ts) and ts[-1] <= 0.95
    rng = np.random.default_rng(37)
    batch = _mixed_batch(rng, B_each=24, m=8, n=8)
    stats_auto, stats_static = [], []
    auto = solve_batched_compacted(batch, segment_k=4,
                                   compact_threshold=None,
                                   stats_out=stats_auto)
    static = solve_batched_compacted(batch, segment_k=4,
                                     compact_threshold=0.5,
                                     stats_out=stats_static)
    np.testing.assert_array_equal(auto.status, static.status)
    np.testing.assert_array_equal(auto.iterations, static.iterations)
    assert (sum(s.elements for s in stats_auto)
            <= sum(s.elements for s in stats_static))


def test_revised_iteration_limit_reported():
    batch = random_lp_batch(np.random.default_rng(2), 6, 10, 10,
                            feasible_start=False)
    res = solve_batched_revised(batch, max_iters=2)
    assert (res.status == ITERATION_LIMIT).any()
    assert np.isnan(res.objective[res.status == ITERATION_LIMIT]).all()
