"""Multi-device behavior (8 host devices via subprocess so the main test
process keeps its single-device jax)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=ROOT,
                       timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_lp_solvers_sharded_match_reference():
    out = _run("""
        import numpy as np
        from repro.core import (OPTIMAL, random_lp_batch,
                                solve_batched_reference, solve_pjit,
                                solve_shard_map)
        from repro.distributed.sharding import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        batch = random_lp_batch(rng, B=37, m=12, n=8, feasible_start=False)
        ref = solve_batched_reference(batch)
        for solver in (solve_pjit, solve_shard_map):
            res = solver(batch, mesh)
            ok = (ref.status == OPTIMAL) & (res.status == OPTIMAL)
            assert (ref.status == res.status).mean() >= 0.95, solver
            rel = abs(ref.objective[ok] - res.objective[ok]) / abs(ref.objective[ok])
            assert rel.max() < 5e-4, solver
        print("LP-OK")
    """)
    assert "LP-OK" in out


def test_lp_shard_map_segmented_compaction_bitwise():
    """solve_shard_map(segment_k=...) — per-shard segment loops + global
    bucket-ladder compaction — must be bit-identical to the single-device
    solver (same pivot sequences, only dead work removed)."""
    out = _run("""
        import numpy as np
        from repro.core import random_lp_batch, solve_batched_jax, solve_shard_map
        from repro.distributed.sharding import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(2)
        batch = random_lp_batch(rng, B=37, m=12, n=8, feasible_start=False)
        jx = solve_batched_jax(batch)
        stats = []
        res = solve_shard_map(batch, mesh, segment_k=4, stats_out=stats)
        assert np.array_equal(jx.status, res.status)
        assert np.array_equal(jx.iterations, res.iterations)
        assert np.array_equal(np.nan_to_num(jx.objective),
                              np.nan_to_num(res.objective))
        assert len(stats) >= 2 and all(s.bucket % 8 == 0 for s in stats)
        print("SEG-OK", len(stats))
    """)
    assert "SEG-OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.distributed.sharding import Sharder, make_mesh
        from repro.distributed.steps import make_train_step
        from repro.optim import get_optimizer
        from repro.launch.cells import build_cell

        cfg = dataclasses.replace(get_config("qwen3-32b").reduced(),
                                  n_heads=4, n_kv_heads=2, d_ff=128)
        rng = np.random.default_rng(0)
        B, S = 8, 32
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}

        # single device
        model0 = build_model(cfg, None)
        params, specs = model0.init(jax.random.PRNGKey(0))
        loss0 = float(model0.loss_fn(params, batch))

        # sharded on (2,4)
        mesh = make_mesh((2, 4), ("data", "model"))
        shd = Sharder(cfg, mesh)
        model1 = build_model(cfg, shd)
        sharded = jax.device_put(params, shd.param_shardings(specs))
        with mesh:
            loss1 = float(jax.jit(model1.loss_fn)(sharded, batch))
        assert abs(loss0 - loss1) < 5e-3, (loss0, loss1)

        # full train step lowers+runs on the mesh
        opt = get_optimizer(cfg.optimizer)
        step = make_train_step(model1, opt)
        opt_state = jax.jit(opt.init)(sharded)
        with mesh:
            p2, o2, metrics = jax.jit(step)(sharded, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))
        print("TRAIN-OK", loss0, loss1)
    """)
    assert "TRAIN-OK" in out


def test_moe_shard_map_matches_local():
    out = _run("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.distributed.sharding import Sharder, make_mesh

        cfg = dataclasses.replace(get_config("llama4-scout-17b-a16e").reduced(),
                                  capacity_factor=100.0)
        rng = np.random.default_rng(1)
        B, S = 8, 16
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
        model0 = build_model(cfg, None)
        params, specs = model0.init(jax.random.PRNGKey(0))
        loss0 = float(model0.loss_fn(params, batch))
        mesh = make_mesh((2, 4), ("data", "model"))
        shd = Sharder(cfg, mesh)
        model1 = build_model(cfg, shd)
        sharded = jax.device_put(params, shd.param_shardings(specs))
        with mesh:
            loss1 = float(jax.jit(model1.loss_fn)(sharded, batch))
        # same routing, same experts; differences only from reduction order
        assert abs(loss0 - loss1) < 5e-3, (loss0, loss1)
        print("MOE-OK", loss0, loss1)
    """)
    assert "MOE-OK" in out


def test_checkpoint_reshard_across_meshes():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.distributed.sharding import make_mesh

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        mesh1 = make_mesh((2, 4), ("data", "model"))
        sh1 = {"w": NamedSharding(mesh1, P("data", "model"))}
        t1 = jax.device_put(tree, sh1)
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(0, t1)
        # elastic restore on a DIFFERENT mesh shape (simulates node loss)
        mesh2 = make_mesh((4, 2), ("data", "model"))
        sh2 = {"w": NamedSharding(mesh2, P("model", "data"))}
        t2 = mgr.restore(0, tree, shardings=sh2)
        np.testing.assert_allclose(np.asarray(t2["w"]), np.asarray(tree["w"]))
        print("RESHARD-OK")
    """)
    assert "RESHARD-OK" in out


def test_dryrun_entrypoint_smoke():
    """The real dryrun script on a small arch (512 host devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "hymba-1.5b",
         "--shape", "decode_32k", "--out", "/tmp/test_dryrun_artifacts"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "OK" in r.stdout
